//! The shared serving reactor: N connections — inbound *and* outbound —
//! O(1) threads.
//!
//! PR 2 replaced the daemon's thread-per-connection accept loop with a
//! readiness loop, but the loop only knew about one kind of socket:
//! accepted clients feeding [`SessionState`] machines. The router tier
//! kept a blocking thread per client session because its sockets came in
//! two roles — clients in front, backend shards behind — and the loop
//! couldn't drive the second kind. This module closes that gap: the loop
//! is now a reusable **reactor** that multiplexes
//!
//! * the listener (accept, connection-cap enforcement),
//! * inbound client connections (sans-IO session framing, per-connection
//!   reorder buffers so pipelined responses flush in request order),
//! * outbound backend connections (non-blocking connect, pending-write
//!   queues, mixed-framed response reads — newline JSON lines and
//!   length-prefixed binary frames — connect/IO deadlines,
//!   reconnect-on-failure via the owning [`App`]),
//! * a self-pipe waker plus an mpsc completion channel for responses
//!   finished on other threads (pool workers).
//!
//! What the bytes *mean* is delegated to an [`App`]: `goomd` instantiates
//! the reactor with [`ServeApp`] (decoded requests dispatch into the
//! worker pool) and the router instantiates it with `router::RelayApp`
//! (decoded requests relay to rendezvous-ranked shards). Framing, decode
//! errors, connection accounting, ordering, and flow control live here,
//! once — `serve` and `route` are two instantiations of the same front.
//!
//! The front itself shards: [`spawn_sharded`] runs `--reactors=N` reactor
//! threads per tier. At N = 1 one reactor owns the listener directly —
//! byte-for-byte the PR-5 shape. At N > 1 a dedicated **acceptor** thread
//! owns the listener and deals accepted sockets round-robin to the
//! reactors over per-reactor channels (waking each target out of `poll`),
//! so no two reactors ever race an `accept(2)`. Each reactor owns its
//! clients end-to-end — sessions never migrate between loops — which is
//! what keeps response ordering and byte-identity untouched: the reorder
//! buffer, completion channel, and idle-deadline sweep of a connection all
//! live on the one reactor that accepted it. Each reactor likewise owns a
//! private [`ReactorStats`] block (no cross-loop counter races on
//! `max_reorder_depth`), registered in a shared [`ReactorSet`] that the
//! `metrics` op rolls up.
//!
//! `poll(2)` is declared directly against the C library std already links
//! (no new dependencies); on Linux the outbound connect path declares
//! `socket(2)`/`connect(2)` the same way so backend connections are truly
//! non-blocking (`EINPROGRESS` + `POLLOUT` + `take_error`). Elsewhere a
//! bounded `connect_timeout` stands in, and on non-unix hosts a portable
//! fallback ticks every couple of milliseconds treating every socket as
//! ready — spurious readiness costs one `WouldBlock` per socket,
//! correctness is unchanged.

use super::faults;
use super::pool::Pool;
use super::protocol::{err_line, num, obj, Payload, Request, Wire, FRAME_HEADER, FRAME_MAGIC};
use super::session::{dispatch, Job, ServerInner, SessionEvent, SessionState, Sink};
use crate::coordinator::Metrics;
use crate::obs::{self, ReqCtx, Stage};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Bytes read per `read(2)` call.
const READ_CHUNK: usize = 64 * 1024;
/// Stop reading from a client whose un-flushed output exceeds this
/// (the client isn't draining responses; don't buffer for it unboundedly).
const MAX_OUTBUF: usize = 4 << 20;
/// Poll timeout: an upper bound on shutdown latency and deadline-sweep
/// granularity, not a serving rate — I/O and completions wake the loop
/// immediately.
const POLL_TIMEOUT_MS: i32 = 500;
/// Cap on one framed backend response line (scan results can run large,
/// but a runaway backend must not buffer unboundedly into the reactor).
pub const MAX_RESPONSE_BYTES: usize = 32 << 20;
/// Cap on a backend connection's pending-write queue. A backend that
/// stops draining its socket must not let the router buffer request
/// bytes without limit; past this it is declared down and its requests
/// fail over. Far above any legitimate transient (it is ~64 max-size
/// request lines), so it only trips on a genuinely stuck peer.
const MAX_BACKEND_OUTBUF: usize = 64 << 20;
/// Bound on establishing a backend connection: a blackholed shard must
/// become a down event (and a failover), not a hung relay.
pub const BACKEND_CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Bound on one backend answer while requests are outstanding. Generous —
/// requests at the protocol's compute bounds legitimately take a while —
/// but finite, so a shard that accepts and then never answers still trips
/// the failover path.
pub const BACKEND_IO_TIMEOUT: Duration = Duration::from_secs(120);

/// A finished wire-ready response payload for connection `.0`, request
/// slot `.1` — a JSON line or a binary frame; the reactor never looks
/// inside either.
type Completion = (u64, u64, Payload);

/// External control surface of one reactor: `shutdown` stops the loop on
/// its next wakeup (best-effort final flush, then sockets close);
/// `drain` stops accepting and lets every connection reach quiescence —
/// responses owed are computed, reordered, and flushed — before the loop
/// returns. Both are one-way latches set by the owner and observed on the
/// loop's next iteration (pair with a [`Waker::wake`]).
#[derive(Default)]
pub struct LoopCtl {
    pub shutdown: AtomicBool,
    pub drain: AtomicBool,
}

/// Front-of-house knobs every reactor instantiation shares.
#[derive(Debug, Clone)]
pub struct FrontConfig {
    /// Busy-line prefix: "server" for goomd, "router" for the relay tier
    /// (keeps rejection lines byte-identical to the pre-reactor fronts).
    pub service: &'static str,
    pub max_request_bytes: usize,
    pub max_connections: usize,
    pub retry_after_ms: u64,
    /// Close an inbound connection silent this long with nothing in
    /// flight (`Duration::ZERO` disables). Outbound backends already get
    /// connect/IO deadline sweeps; this is the inbound twin — a slowloris
    /// client holding a half-written line must not pin a connection slot
    /// (and its poll fd) forever.
    pub idle_timeout: Duration,
}

/// Reactor observability: exported through the `metrics` op (router and
/// daemon alike) under `"reactor"`. All monotonic except the high-water
/// reorder depth. With a sharded front each reactor owns a private block
/// (registered in a [`ReactorSet`]): `max_reorder_depth` is a per-loop
/// high-water mark, not a cross-loop shared counter, and the rollup takes
/// the max across blocks rather than racing N loops on one atomic.
#[derive(Default)]
pub struct ReactorStats {
    /// Loop iterations (each: poll + accept + I/O + flush).
    pub loop_iterations: AtomicU64,
    /// Times the self-pipe waker pulled the loop out of `poll`.
    pub wakeups: AtomicU64,
    /// Inbound client connections accepted.
    pub fds_accepted: AtomicU64,
    /// Outbound backend connections that completed their connect.
    pub fds_connected: AtomicU64,
    /// High-water mark of any connection's reorder buffer: how far ahead
    /// pipelined completions ran of the response they waited behind.
    pub max_reorder_depth: AtomicU64,
}

impl ReactorStats {
    fn raise_reorder_depth(&self, depth: u64) {
        self.max_reorder_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// JSON form for the `metrics` op (`"reactor"` sub-object).
    pub fn to_json(&self) -> Json {
        let g = |a: &AtomicU64| num(a.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("loop_iterations", g(&self.loop_iterations)),
            ("wakeups", g(&self.wakeups)),
            ("fds_accepted", g(&self.fds_accepted)),
            ("fds_connected", g(&self.fds_connected)),
            ("max_reorder_depth", g(&self.max_reorder_depth)),
        ])
    }
}

/// Registry of the per-reactor [`ReactorStats`] blocks behind one front.
/// Each reactor registers its own block at spawn; the `metrics` op rolls
/// them up under `"reactor"` — sums for the monotonic counters, max for
/// the reorder high-water — plus a `"per_reactor"` breakdown array, so a
/// sharded front exports the same top-level counter names a single
/// reactor always has.
#[derive(Default)]
pub struct ReactorSet {
    stats: Mutex<Vec<Arc<ReactorStats>>>,
}

impl ReactorSet {
    /// Allocate and register the stats block for one reactor.
    pub fn register(&self) -> Arc<ReactorStats> {
        let block = Arc::new(ReactorStats::default());
        self.stats.lock().expect("reactor set lock").push(Arc::clone(&block));
        block
    }

    /// Snapshot of every registered block (test/introspection helper).
    pub fn blocks(&self) -> Vec<Arc<ReactorStats>> {
        self.stats.lock().expect("reactor set lock").clone()
    }

    /// Rolled-up JSON form for the `metrics` op (`"reactor"` sub-object):
    /// the five classic counters aggregated across reactors, plus
    /// `"reactors"` (the shard count) and `"per_reactor"` (one classic
    /// block per loop, in spawn order).
    pub fn to_json(&self) -> Json {
        let blocks = self.blocks();
        let sum = |f: fn(&ReactorStats) -> &AtomicU64| {
            num(blocks.iter().map(|b| f(b).load(Ordering::Relaxed)).sum::<u64>() as f64)
        };
        let peak = blocks
            .iter()
            .map(|b| b.max_reorder_depth.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0);
        obj(vec![
            ("loop_iterations", sum(|b| &b.loop_iterations)),
            ("wakeups", sum(|b| &b.wakeups)),
            ("fds_accepted", sum(|b| &b.fds_accepted)),
            ("fds_connected", sum(|b| &b.fds_connected)),
            ("max_reorder_depth", num(peak as f64)),
            ("reactors", num(blocks.len() as f64)),
            ("per_reactor", Json::Arr(blocks.iter().map(|b| b.to_json()).collect())),
        ])
    }
}

/// Protocol brain of one reactor instantiation. The reactor owns sockets,
/// framing, ordering, and accounting; the app decides what a decoded
/// request *does* and what framed backend lines *mean*.
pub trait App: Send + 'static {
    /// Front-of-house limits (read once at spawn).
    fn front(&self) -> FrontConfig;
    /// The metrics registry shared connection accounting increments.
    fn metrics(&self) -> &Mutex<Metrics>;
    /// The stats block this reactor publishes (read once at spawn).
    fn stats(&self) -> Arc<ReactorStats>;
    /// One decoded client request on `(conn, seq)` with its observability
    /// context (wire id to echo, trace id when sampled) and the encoding it
    /// arrived in (`wire`; the response must answer in kind). Answer now
    /// via [`Core::complete`], later via [`Core::reply_to`], or by relaying
    /// through a backend connection.
    #[allow(clippy::too_many_arguments)]
    fn on_request(
        &mut self,
        core: &mut Core,
        conn: u64,
        seq: u64,
        req: Request,
        ctx: ReqCtx,
        wire: Wire,
    );
    /// One complete newline-framed line arrived from backend `backend`
    /// (terminator stripped, trailing whitespace trimmed).
    fn on_backend_line(&mut self, _core: &mut Core, _backend: u64, _line: String) {}
    /// One complete binary frame arrived from backend `backend` (header
    /// included, verbatim wire bytes — relays forward it without a decode).
    fn on_backend_frame(&mut self, _core: &mut Core, _backend: u64, _frame: Vec<u8>) {}
    /// Backend connection `backend` is gone: connect failed, EOF, I/O
    /// error, oversized frame, or deadline. Already deregistered — every
    /// line it still owed is lost and must be failed over or failed out.
    fn on_backend_down(&mut self, _core: &mut Core, _backend: u64) {}
}

/// Wakes the loop out of `poll` from other threads (self-pipe trick).
pub struct Waker {
    #[cfg(unix)]
    tx: std::os::unix::net::UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            // One byte is enough; WouldBlock means a wake is already queued.
            let _ = (&self.tx).write(&[1u8]);
        }
    }
}

#[cfg(unix)]
fn waker_pair() -> io::Result<(Waker, std::os::unix::net::UnixStream)> {
    let (rx, tx) = std::os::unix::net::UnixStream::pair()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((Waker { tx }, rx))
}

#[cfg(unix)]
mod sys {
    //! The C declarations the reactor needs. std links libc on every unix
    //! target, so this adds no dependency — just prototypes.
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    // nfds_t is `unsigned long` on Linux (pointer-width) and `unsigned
    // int` on the BSD family — match the ABI, not just the OS name.
    #[cfg(all(target_os = "linux", target_pointer_width = "64"))]
    pub type Nfds = u64;
    #[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
    pub type Nfds = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
    }

    // Outbound non-blocking connect — Linux on the common arches only:
    // SOCK_NONBLOCK and EINPROGRESS are generic across these, but mips /
    // sparc / alpha renumber them, so exotic arches (and other unixes)
    // fall back to a bounded blocking connect instead of silently
    // misclassifying every in-progress connect as a hard error.
    #[cfg(all(
        target_os = "linux",
        any(
            target_arch = "x86_64",
            target_arch = "x86",
            target_arch = "aarch64",
            target_arch = "arm",
            target_arch = "riscv64"
        )
    ))]
    pub mod connect {
        pub const AF_INET: i32 = 2;
        pub const AF_INET6: i32 = 10;
        pub const SOCK_STREAM: i32 = 1;
        pub const SOCK_NONBLOCK: i32 = 0o4000;
        pub const SOCK_CLOEXEC: i32 = 0o2000000;
        pub const EINPROGRESS: i32 = 115;

        #[repr(C)]
        pub struct SockAddrIn {
            pub family: u16,
            /// Big-endian on the wire.
            pub port: u16,
            /// Network-order octets.
            pub addr: [u8; 4],
            pub zero: [u8; 8],
        }

        #[repr(C)]
        pub struct SockAddrIn6 {
            pub family: u16,
            pub port: u16,
            pub flowinfo: u32,
            pub addr: [u8; 16],
            pub scope_id: u32,
        }

        extern "C" {
            pub fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
            pub fn connect(fd: i32, addr: *const u8, len: u32) -> i32;
        }
    }
}

/// Begin a TCP connect without blocking the loop. Returns the stream and
/// whether the connect is still in progress (completion — success or
/// refusal — arrives as `POLLOUT` and is resolved via `take_error`).
#[cfg(all(
    target_os = "linux",
    any(
        target_arch = "x86_64",
        target_arch = "x86",
        target_arch = "aarch64",
        target_arch = "arm",
        target_arch = "riscv64"
    )
))]
fn connect_nonblocking(sa: &SocketAddr) -> io::Result<(TcpStream, bool)> {
    use std::os::unix::io::FromRawFd;
    use sys::connect as c;

    let ty = c::SOCK_STREAM | c::SOCK_NONBLOCK | c::SOCK_CLOEXEC;
    let (fd, rc) = unsafe {
        match sa {
            SocketAddr::V4(v4) => {
                let sin = c::SockAddrIn {
                    family: c::AF_INET as u16,
                    port: v4.port().to_be(),
                    addr: v4.ip().octets(),
                    zero: [0; 8],
                };
                let fd = c::socket(c::AF_INET, ty, 0);
                if fd < 0 {
                    return Err(io::Error::last_os_error());
                }
                let rc = c::connect(
                    fd,
                    std::ptr::addr_of!(sin).cast(),
                    std::mem::size_of::<c::SockAddrIn>() as u32,
                );
                (fd, rc)
            }
            SocketAddr::V6(v6) => {
                let sin6 = c::SockAddrIn6 {
                    family: c::AF_INET6 as u16,
                    port: v6.port().to_be(),
                    flowinfo: v6.flowinfo(),
                    addr: v6.ip().octets(),
                    scope_id: v6.scope_id(),
                };
                let fd = c::socket(c::AF_INET6, ty, 0);
                if fd < 0 {
                    return Err(io::Error::last_os_error());
                }
                let rc = c::connect(
                    fd,
                    std::ptr::addr_of!(sin6).cast(),
                    std::mem::size_of::<c::SockAddrIn6>() as u32,
                );
                (fd, rc)
            }
        }
    };
    // Wrap immediately (no intervening syscall, so errno from `connect`
    // is still intact below): every exit path closes the fd on drop.
    let stream = unsafe { TcpStream::from_raw_fd(fd) };
    if rc == 0 {
        return Ok((stream, false));
    }
    let err = io::Error::last_os_error();
    if err.raw_os_error() == Some(c::EINPROGRESS) {
        return Ok((stream, true));
    }
    Err(err)
}

/// Portable stand-in: a bounded blocking connect, then non-blocking I/O
/// as usual. Known degradation on these hosts: the relay's retry ladder
/// can walk several blackholed backends synchronously, stalling the loop
/// up to 2 s × 2 tries × N backends for one doomed request — bounded,
/// but real; the Linux fast path exists precisely to avoid it.
#[cfg(not(all(
    target_os = "linux",
    any(
        target_arch = "x86_64",
        target_arch = "x86",
        target_arch = "aarch64",
        target_arch = "arm",
        target_arch = "riscv64"
    )
)))]
fn connect_nonblocking(sa: &SocketAddr) -> io::Result<(TcpStream, bool)> {
    let stream = TcpStream::connect_timeout(sa, BACKEND_CONNECT_TIMEOUT)?;
    stream.set_nonblocking(true)?;
    Ok((stream, false))
}

/// One live inbound connection: its socket, protocol state, and the
/// reorder buffer that keeps pipelined responses in request order.
struct Conn {
    stream: TcpStream,
    session: SessionState,
    /// Bytes framed and waiting for the socket to accept them.
    out: Vec<u8>,
    /// Next request slot to assign.
    next_seq: u64,
    /// Next slot whose response may be flushed.
    emit_seq: u64,
    /// Completed wire payloads waiting on earlier slots.
    ready: BTreeMap<u64, Payload>,
    /// Last inbound bytes (or accept) — the idle-deadline clock.
    last_activity: Instant,
    read_closed: bool,
    dead: bool,
    readable: bool,
}

impl Conn {
    fn finished(&self) -> bool {
        self.read_closed && self.emit_seq == self.next_seq && self.out.is_empty()
    }

    /// Nothing owed in either direction: every assigned slot has flushed
    /// and no completed line waits behind another. Such a connection can
    /// close without any client observing a truncated exchange — the
    /// drain path's per-connection exit condition.
    fn quiescent(&self) -> bool {
        self.emit_seq == self.next_seq && self.out.is_empty() && self.ready.is_empty()
    }
}

/// One loop-managed outbound connection to a backend.
struct BackendConn {
    stream: TcpStream,
    /// Non-blocking connect still in progress (resolved on `POLLOUT`).
    connecting: bool,
    opened: Instant,
    /// IO-deadline clock: re-armed when a response arrives and when the
    /// connection goes from idle to owing one. Deliberately NOT refreshed
    /// by writes — a shard that keeps accepting requests but never
    /// answers must still trip the deadline.
    last_activity: Instant,
    /// Request bytes queued behind the socket's send buffer.
    out: Vec<u8>,
    /// Partial response message — a line awaiting its terminator or a
    /// binary frame awaiting its declared payload.
    inbuf: Vec<u8>,
    /// Bytes of `inbuf` already scanned for a line terminator — framing
    /// must stay linear while a multi-MiB response dribbles in across
    /// reads (binary frames declare their length and never scan).
    scanned: usize,
    /// Response messages owed to the app (one per request sent, either
    /// framing).
    awaiting: usize,
    readable: bool,
    writable: bool,
}

/// Thread handles of one (possibly sharded) serving front: the reactor
/// threads with their wakers, plus — only when sharded — the acceptor
/// thread that owns the listener.
pub struct FrontHandles {
    pub reactors: Vec<JoinHandle<()>>,
    pub wakers: Vec<Arc<Waker>>,
    pub acceptor: Option<JoinHandle<()>>,
}

impl FrontHandles {
    /// Kick every reactor out of `poll` — pair with a `LoopCtl` latch.
    pub fn wake_all(&self) {
        for w in &self.wakers {
            w.wake();
        }
    }

    /// Join the acceptor (first, so no new sockets land mid-teardown)
    /// and then every reactor. Idempotent: joined handles drain out.
    pub fn join_all(&mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.reactors.drain(..) {
            let _ = h.join();
        }
    }
}

/// Start one reactor thread per app in `apps`, all serving `listener`.
///
/// With one app this is exactly the classic single-reactor front: the
/// loop thread owns the listener and accepts directly. With N > 1 apps an
/// acceptor thread owns the listener and deals accepted sockets
/// round-robin to the reactors over per-reactor channels (waking the
/// target loop), so accept order is deterministic and no loop contends on
/// `accept(2)`. Connection-cap enforcement stays global either way via a
/// shared connection count, and conn ids are strided by reactor index so
/// they remain globally unique across loops.
pub fn spawn_sharded<A: App>(
    name: &str,
    listener: TcpListener,
    apps: Vec<A>,
    ctl: Arc<LoopCtl>,
) -> io::Result<FrontHandles> {
    assert!(!apps.is_empty(), "a front needs at least one reactor");
    let shards = apps.len();
    let conn_count = Arc::new(AtomicUsize::new(0));
    if shards == 1 {
        let app = apps.into_iter().next().expect("one app");
        let (handle, waker) =
            spawn_reactor(name.to_string(), Some(listener), None, app, ctl, conn_count, 0, 1)?;
        return Ok(FrontHandles { reactors: vec![handle], wakers: vec![waker], acceptor: None });
    }
    let mut reactors = Vec::with_capacity(shards);
    let mut wakers = Vec::with_capacity(shards);
    let mut lanes = Vec::with_capacity(shards);
    for (i, app) in apps.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let (handle, waker) = spawn_reactor(
            format!("{name}-{i}"),
            None,
            Some(rx),
            app,
            Arc::clone(&ctl),
            Arc::clone(&conn_count),
            i as u64,
            shards as u64,
        )?;
        lanes.push((tx, Arc::clone(&waker)));
        reactors.push(handle);
        wakers.push(waker);
    }
    let acceptor = std::thread::Builder::new()
        .name(format!("{name}-acceptor"))
        .spawn(move || acceptor_loop(listener, lanes, ctl))?;
    Ok(FrontHandles { reactors, wakers, acceptor: Some(acceptor) })
}

/// Start one reactor thread. Exactly one of `listener` (solo front: the
/// loop accepts directly) and `incoming` (sharded front: the acceptor
/// deals sockets over this channel) is `Some`.
#[allow(clippy::too_many_arguments)]
fn spawn_reactor<A: App>(
    name: String,
    listener: Option<TcpListener>,
    incoming: Option<mpsc::Receiver<TcpStream>>,
    app: A,
    ctl: Arc<LoopCtl>,
    conn_count: Arc<AtomicUsize>,
    conn_id_start: u64,
    conn_id_step: u64,
) -> io::Result<(JoinHandle<()>, Arc<Waker>)> {
    #[cfg(unix)]
    let (waker, wake_rx) = waker_pair()?;
    #[cfg(not(unix))]
    let waker = Waker {};
    let waker = Arc::new(waker);
    let loop_waker = Arc::clone(&waker);
    let front = app.front();
    let stats = app.stats();
    let handle = std::thread::Builder::new().name(name).spawn(move || {
        let (tx, rx) = mpsc::channel::<Completion>();
        Reactor {
            core: Core {
                listener,
                incoming,
                front,
                stats,
                waker: loop_waker,
                #[cfg(unix)]
                wake_rx,
                completions_tx: tx,
                completions_rx: rx,
                conns: HashMap::new(),
                next_conn_id: conn_id_start,
                conn_id_step,
                conn_count,
                backends: HashMap::new(),
                next_backend_id: 0,
                listener_ready: false,
                accepting: true,
            },
            app,
            ctl,
        }
        .run();
    })?;
    Ok((handle, waker))
}

/// Wait (bounded) for the listener to become readable so the acceptor
/// neither spins on a non-blocking socket nor sleeps through a burst.
#[cfg(unix)]
fn acceptor_wait(listener: &TcpListener) {
    use std::os::unix::io::AsRawFd;
    let mut fds =
        [sys::PollFd { fd: listener.as_raw_fd(), events: sys::POLLIN, revents: 0 }];
    // Bounded timeout so shutdown/drain latches are observed promptly.
    unsafe {
        sys::poll(fds.as_mut_ptr(), 1 as sys::Nfds, 200);
    }
}

#[cfg(not(unix))]
fn acceptor_wait(_listener: &TcpListener) {
    std::thread::sleep(Duration::from_millis(2));
}

/// The sharded front's acceptor: sole owner of the listener, dealing each
/// accepted socket to the next reactor round-robin and waking it. Exits —
/// dropping the listener, so new connections are refused at the kernel —
/// as soon as shutdown or drain latches; sockets already dealt stay with
/// their reactor and drain there.
fn acceptor_loop(
    listener: TcpListener,
    lanes: Vec<(mpsc::Sender<TcpStream>, Arc<Waker>)>,
    ctl: Arc<LoopCtl>,
) {
    let mut next = 0usize;
    loop {
        if ctl.shutdown.load(Ordering::SeqCst) || ctl.drain.load(Ordering::SeqCst) {
            return;
        }
        acceptor_wait(&listener);
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let (tx, waker) = &lanes[next % lanes.len()];
                    next += 1;
                    if tx.send(stream).is_ok() {
                        waker.wake();
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // e.g. EMFILE — back off instead of spinning (see
                    // the solo accept path for the same reasoning).
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }
}

/// Socket-facing reactor state, handed to [`App`] hooks so the protocol
/// brain can complete responses and drive backend connections without
/// owning any I/O itself.
pub struct Core {
    /// `Some` on a solo front (the loop accepts directly); `None` on a
    /// sharded front, where the acceptor thread owns the listener.
    listener: Option<TcpListener>,
    /// Sharded front only: sockets the acceptor dealt to this reactor.
    incoming: Option<mpsc::Receiver<TcpStream>>,
    front: FrontConfig,
    stats: Arc<ReactorStats>,
    waker: Arc<Waker>,
    #[cfg(unix)]
    wake_rx: std::os::unix::net::UnixStream,
    completions_tx: mpsc::Sender<Completion>,
    completions_rx: mpsc::Receiver<Completion>,
    conns: HashMap<u64, Conn>,
    next_conn_id: u64,
    /// Conn-id stride (= reactor count): ids stay globally unique across
    /// the loops of a sharded front without any cross-loop coordination.
    conn_id_step: u64,
    /// Open inbound connections across *every* reactor of this front —
    /// the connection cap is a front-wide limit, not a per-loop one.
    conn_count: Arc<AtomicUsize>,
    backends: HashMap<u64, BackendConn>,
    next_backend_id: u64,
    listener_ready: bool,
    /// Cleared on drain: the listener leaves the poll set and pending
    /// connections stay unaccepted (they reset when the loop exits).
    accepting: bool,
}

impl Core {
    /// Park the finished response for request slot (`conn`, `seq`); it
    /// flushes once every earlier slot has answered. A completion for a
    /// since-closed connection is dropped.
    pub fn complete(&mut self, conn: u64, seq: u64, payload: impl Into<Payload>) {
        if let Some(c) = self.conns.get_mut(&conn) {
            c.ready.insert(seq, payload.into());
            self.stats.raise_reorder_depth(c.ready.len() as u64);
        }
    }

    /// Requests connection `conn` has in flight (assigned slots whose
    /// responses have not flushed, the just-assigned one included) — the
    /// admission controller's per-client fairness signal.
    pub fn conn_inflight(&self, conn: u64) -> usize {
        self.conns
            .get(&conn)
            .map(|c| (c.next_seq - c.emit_seq) as usize)
            .unwrap_or(0)
    }

    /// A [`Sink`] for request slot (`conn`, `seq`): routes the finished
    /// wire payload back through the completion channel and wakes the
    /// loop. Works from any thread.
    pub fn reply_to(&self, conn: u64, seq: u64) -> Sink {
        let tx = self.completions_tx.clone();
        let waker = Arc::clone(&self.waker);
        Box::new(move |payload| {
            let _ = tx.send((conn, seq, payload));
            waker.wake();
        })
    }

    /// Open a loop-managed connection toward `addr` (non-blocking on
    /// Linux). Immediate resolution/refusal errors return `Err`; an
    /// in-progress connect returns its id and fails asynchronously through
    /// [`App::on_backend_down`] if the backend is unreachable.
    pub fn backend_open(&mut self, addr: &str) -> io::Result<u64> {
        if faults::enabled() {
            match faults::decide(faults::Site::BackendConnect) {
                faults::Fault::Drop => {
                    return Err(io::Error::new(
                        io::ErrorKind::ConnectionRefused,
                        "fault-injected connect drop",
                    ));
                }
                faults::Fault::Stall(d) => std::thread::sleep(d),
                _ => {}
            }
        }
        let sockaddr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "backend address resolves to nothing")
        })?;
        let (stream, connecting) = connect_nonblocking(&sockaddr)?;
        if !connecting {
            self.stats.fds_connected.fetch_add(1, Ordering::Relaxed);
        }
        let id = self.next_backend_id;
        self.next_backend_id += 1;
        let now = Instant::now();
        self.backends.insert(
            id,
            BackendConn {
                stream,
                connecting,
                opened: now,
                last_activity: now,
                out: Vec::new(),
                inbuf: Vec::new(),
                scanned: 0,
                awaiting: 0,
                // An in-progress connect must wait for poll's POLLOUT
                // before the first write (or `take_error` check) — writing
                // earlier would misread the socket's state. An
                // already-connected socket serves immediately.
                readable: !connecting,
                writable: !connecting,
            },
        );
        Ok(id)
    }

    /// Queue one request payload on backend `backend` — a JSON line (the
    /// terminator is appended by the payload's writer) or a binary frame,
    /// sent verbatim. Returns `false` when the connection is already gone.
    pub fn backend_send(&mut self, backend: u64, payload: &Payload) -> bool {
        match self.backends.get_mut(&backend) {
            Some(b) => {
                if b.awaiting == 0 {
                    // Idle → owing: (re)arm the IO deadline. It measures
                    // silence since the oldest outstanding request, so a
                    // long-idle pooled connection is not reaped the moment
                    // a new request lands on it.
                    b.last_activity = Instant::now();
                }
                payload.write_wire(&mut b.out);
                b.awaiting += 1;
                true
            }
            None => false,
        }
    }

    /// Whether backend connection `backend` is still registered.
    pub fn backend_alive(&self, backend: u64) -> bool {
        self.backends.contains_key(&backend)
    }

    /// Deregister (and close) backend connection `backend` without a down
    /// event — for abandoning a protocol-desynced connection that owes
    /// nothing. Dropping the entry closes the socket; without this the fd
    /// would stay registered (and polled) until the remote side closed.
    pub fn backend_close(&mut self, backend: u64) {
        self.backends.remove(&backend);
    }

    /// Block until something needs service (or the poll timeout elapses):
    /// a new connection, readable/writable sockets, or a waker byte from a
    /// completed job.
    #[cfg(unix)]
    fn wait_ready(&mut self) {
        use std::os::unix::io::AsRawFd;

        #[derive(Clone, Copy)]
        enum Token {
            Client(u64),
            Backend(u64),
        }

        let cap = self.conns.len() + self.backends.len() + 2;
        let mut fds: Vec<sys::PollFd> = Vec::with_capacity(cap);
        let mut tokens: Vec<Option<Token>> = Vec::with_capacity(cap);
        fds.push(sys::PollFd {
            // poll(2) ignores negative fds, so a draining (or sharded —
            // no listener here) loop parks the listener slot instead of
            // shifting every index below it.
            fd: match &self.listener {
                Some(l) if self.accepting => l.as_raw_fd(),
                _ => -1,
            },
            events: sys::POLLIN,
            revents: 0,
        });
        tokens.push(None);
        fds.push(sys::PollFd {
            fd: self.wake_rx.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        tokens.push(None);
        for (&id, conn) in &mut self.conns {
            conn.readable = false;
            let mut events = 0i16;
            if !conn.read_closed && conn.out.len() <= MAX_OUTBUF {
                events |= sys::POLLIN;
            }
            if !conn.out.is_empty() {
                events |= sys::POLLOUT;
            }
            if events == 0 {
                continue;
            }
            fds.push(sys::PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
            tokens.push(Some(Token::Client(id)));
        }
        for (&id, b) in &mut self.backends {
            b.readable = false;
            b.writable = false;
            let mut events = sys::POLLIN;
            if b.connecting || !b.out.is_empty() {
                events |= sys::POLLOUT;
            }
            fds.push(sys::PollFd { fd: b.stream.as_raw_fd(), events, revents: 0 });
            tokens.push(Some(Token::Backend(id)));
        }
        let n = unsafe {
            sys::poll(fds.as_mut_ptr(), fds.len() as sys::Nfds, POLL_TIMEOUT_MS)
        };
        self.listener_ready = false;
        if n < 0 {
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                // Not expected; avoid a hot error spin.
                std::thread::sleep(Duration::from_millis(5));
            }
            return;
        }
        self.listener_ready = fds[0].revents != 0;
        if fds[1].revents != 0 {
            self.stats.wakeups.fetch_add(1, Ordering::Relaxed);
            // Swallow queued wake bytes; completions drain separately.
            let mut sink = [0u8; 256];
            while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }
        for (fd, token) in fds.iter().zip(&tokens).skip(2) {
            let hang = fd.revents & (sys::POLLERR | sys::POLLHUP) != 0;
            match token {
                Some(Token::Client(id)) => {
                    if fd.revents & sys::POLLIN != 0 || hang {
                        if let Some(conn) = self.conns.get_mut(id) {
                            // A hangup on a read-closed conn is surfaced by
                            // the flush path instead.
                            conn.readable = !conn.read_closed;
                        }
                    }
                }
                Some(Token::Backend(id)) => {
                    if let Some(b) = self.backends.get_mut(id) {
                        // A hangup or error must reach the read/connect
                        // path so the death is observed and failed over.
                        b.readable = fd.revents & sys::POLLIN != 0 || hang;
                        b.writable = fd.revents & sys::POLLOUT != 0 || hang;
                    }
                }
                None => {}
            }
        }
    }

    /// Portable fallback: tick and treat everything as ready. Non-blocking
    /// sockets make spurious readiness harmless (one `WouldBlock` each).
    #[cfg(not(unix))]
    fn wait_ready(&mut self) {
        std::thread::sleep(Duration::from_millis(2));
        self.listener_ready = self.accepting && self.listener.is_some();
        for conn in self.conns.values_mut() {
            conn.readable = !conn.read_closed && conn.out.len() <= MAX_OUTBUF;
        }
        for b in self.backends.values_mut() {
            b.readable = true;
            b.writable = true;
        }
    }
}

struct Reactor<A: App> {
    core: Core,
    app: A,
    ctl: Arc<LoopCtl>,
}

impl<A: App> Reactor<A> {
    fn run(mut self) {
        loop {
            self.core.wait_ready();
            self.core.stats.loop_iterations.fetch_add(1, Ordering::Relaxed);
            if self.ctl.shutdown.load(Ordering::SeqCst) {
                // Best-effort final pass: pending completions (e.g. pool
                // teardown's shutdown-error lines) are delivered as far as
                // the sockets will take them before closing.
                self.drain_completions();
                self.flush_conns();
                return;
            }
            let draining = self.ctl.drain.load(Ordering::SeqCst);
            if draining {
                self.core.accepting = false;
            }
            self.accept_ready();
            self.read_ready();
            self.backend_io();
            self.sweep_backend_deadlines();
            self.sweep_client_deadlines();
            self.drain_completions();
            self.flush_conns();
            if draining {
                // Connections that owe nothing in either direction close
                // now; the rest stay until their in-flight responses have
                // computed, reordered, and flushed — then the next
                // iteration catches them quiescent. The loop (and with it
                // the listener) exits only once every connection has
                // closed cleanly: no client sees a mid-line disconnect.
                for c in self.core.conns.values_mut() {
                    if c.quiescent() {
                        c.dead = true;
                    }
                }
            }
            let before = self.core.conns.len();
            self.core.conns.retain(|_, c| !c.dead && !c.finished());
            let removed = before - self.core.conns.len();
            if removed > 0 {
                self.core.conn_count.fetch_sub(removed, Ordering::Relaxed);
            }
            if draining && self.core.conns.is_empty() {
                self.drain_completions();
                return;
            }
        }
    }

    fn accept_ready(&mut self) {
        // Sharded front: drain sockets the acceptor dealt us. Sockets
        // still queued when draining starts are dropped with the channel
        // when the loop exits (they reset, same as an unaccepted backlog).
        if let Some(rx) = self.core.incoming.take() {
            if self.core.accepting {
                while let Ok(stream) = rx.try_recv() {
                    self.on_accept(stream);
                }
            }
            self.core.incoming = Some(rx);
        }
        if !self.core.listener_ready {
            return;
        }
        loop {
            let accepted = match &self.core.listener {
                Some(listener) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _peer)) => self.on_accept(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    // e.g. EMFILE: the pending connection stays in the
                    // backlog, so poll would report the listener readable
                    // again immediately — back off briefly instead of
                    // spinning the loop at 100% CPU.
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn on_accept(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return; // drops (closes) the stream
        }
        let max_connections = self.core.front.max_connections.max(1);
        if self.core.conn_count.load(Ordering::Relaxed) >= max_connections {
            self.app
                .metrics()
                .lock()
                .expect("metrics lock")
                .incr("connections_rejected", 1);
            let mut line = err_line(
                &format!(
                    "{} busy: connection limit ({max_connections}) reached",
                    self.core.front.service
                ),
                Some(self.core.front.retry_after_ms),
            );
            line.push('\n');
            // Best-effort: a fresh socket's send buffer is empty, so this
            // short line fits or the client is already gone.
            let _ = (&stream).write(line.as_bytes());
            return; // drops (closes) the stream
        }
        self.app.metrics().lock().expect("metrics lock").incr("connections", 1);
        self.core.stats.fds_accepted.fetch_add(1, Ordering::Relaxed);
        self.core.conn_count.fetch_add(1, Ordering::Relaxed);
        let id = self.core.next_conn_id;
        self.core.next_conn_id += self.core.conn_id_step;
        if obs::enabled() {
            obs::record_conn(id, self.core.front.service, Stage::Accept, obs::now_us(), 0.0);
        }
        self.core.conns.insert(
            id,
            Conn {
                stream,
                session: SessionState::new(self.core.front.max_request_bytes),
                out: Vec::new(),
                next_seq: 0,
                emit_seq: 0,
                ready: BTreeMap::new(),
                last_activity: Instant::now(),
                read_closed: false,
                dead: false,
                // Serve bytes that raced ahead of the first poll.
                readable: true,
            },
        );
    }

    fn read_ready(&mut self) {
        let ids: Vec<u64> = self
            .core
            .conns
            .iter()
            .filter(|(_, c)| c.readable && !c.dead && !c.read_closed)
            .map(|(&id, _)| id)
            .collect();
        let mut buf = vec![0u8; READ_CHUNK];
        for id in ids {
            let mut events = Vec::new();
            let conn = self.core.conns.get_mut(&id).expect("conn exists");
            if faults::enabled() {
                match faults::decide(faults::Site::ClientRead) {
                    faults::Fault::Drop => {
                        conn.dead = true;
                        continue;
                    }
                    faults::Fault::Stall(d) => std::thread::sleep(d),
                    _ => {}
                }
            }
            // Fairness budget: one firehosing client must not pin the loop;
            // leftover bytes stay in the kernel buffer and poll reports the
            // socket readable again next iteration.
            let mut budget = 16;
            loop {
                if budget == 0 {
                    break;
                }
                budget -= 1;
                match (&conn.stream).read(&mut buf) {
                    Ok(0) => {
                        conn.session.on_eof(&mut events);
                        break;
                    }
                    Ok(n) => {
                        conn.last_activity = Instant::now();
                        conn.session.on_bytes(&buf[..n], &mut events);
                        if conn.session.is_closed() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        self.app
                            .metrics()
                            .lock()
                            .expect("metrics lock")
                            .incr("connection_errors", 1);
                        conn.dead = true;
                        break;
                    }
                }
            }
            self.handle_events(id, events);
        }
    }

    fn handle_events(&mut self, id: u64, events: Vec<SessionEvent>) {
        // Counters accumulate across the whole read burst and land in ONE
        // metrics-lock acquisition below — a pipelining client used to cost
        // one lock round-trip per event on the reactor thread.
        let mut requests = 0u64;
        let mut oversized = 0u64;
        for ev in events {
            match ev {
                SessionEvent::Request(req, wire_id, wire) => {
                    requests += 1;
                    let ctx = ReqCtx::admit(wire_id);
                    if let Some(trace) = &ctx.trace {
                        obs::record(
                            trace,
                            self.core.front.service,
                            Stage::Decode,
                            obs::now_us(),
                            0.0,
                        );
                    }
                    let seq = self.assign_seq(id);
                    self.app.on_request(&mut self.core, id, seq, req, ctx, wire);
                }
                SessionEvent::BadLine(payload) => {
                    requests += 1;
                    let seq = self.assign_seq(id);
                    self.core.complete(id, seq, payload);
                }
                SessionEvent::Oversized(payload) => {
                    oversized += 1;
                    let seq = self.assign_seq(id);
                    self.core.complete(id, seq, payload);
                }
                SessionEvent::Close => {
                    if let Some(c) = self.core.conns.get_mut(&id) {
                        c.read_closed = true;
                    }
                }
            }
        }
        if requests > 0 || oversized > 0 {
            let mut m = self.app.metrics().lock().expect("metrics lock");
            if requests > 0 {
                m.incr("requests_total", requests);
            }
            if oversized > 0 {
                m.incr("oversized_rejects", oversized);
            }
        }
    }

    fn assign_seq(&mut self, id: u64) -> u64 {
        let c = self.core.conns.get_mut(&id).expect("conn exists");
        let seq = c.next_seq;
        c.next_seq += 1;
        seq
    }

    /// Drive every ready backend connection: resolve in-progress connects,
    /// flush pending writes, frame inbound lines for the app, and surface
    /// deaths (EOF, errors, refused connects) as down events.
    fn backend_io(&mut self) {
        let ids: Vec<u64> = self
            .core
            .backends
            .iter()
            .filter(|(_, b)| b.readable || b.writable)
            .map(|(&id, _)| id)
            .collect();
        let mut buf = vec![0u8; READ_CHUNK];
        for id in ids {
            let Some(b) = self.core.backends.get_mut(&id) else { continue };
            let mut down = false;
            if b.writable {
                if b.connecting {
                    match b.stream.take_error() {
                        Ok(None) => {
                            b.connecting = false;
                            b.last_activity = Instant::now();
                            self.core.stats.fds_connected.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(Some(_)) | Err(_) => down = true,
                    }
                }
                if !down && !b.connecting && !b.out.is_empty() {
                    // Note: a successful flush does NOT refresh the IO
                    // deadline — only responses (reads) do.
                    down = !flush_bytes(&b.stream, &mut b.out, faults::Site::BackendWrite);
                }
            }
            let mut msgs = Vec::new();
            if !down && b.readable && !b.connecting && faults::enabled() {
                match faults::decide(faults::Site::BackendRead) {
                    faults::Fault::Drop => down = true,
                    faults::Fault::Stall(d) => std::thread::sleep(d),
                    _ => {}
                }
            }
            if !down && b.readable && !b.connecting {
                let mut budget = 16;
                loop {
                    if budget == 0 {
                        break;
                    }
                    budget -= 1;
                    match (&b.stream).read(&mut buf) {
                        Ok(0) => {
                            down = true;
                            break;
                        }
                        Ok(n) => {
                            b.last_activity = Instant::now();
                            b.inbuf.extend_from_slice(&buf[..n]);
                            if drain_backend_msgs(b, &mut msgs).is_err() {
                                // A message outgrew the relay cap; its
                                // remainder would desync every later
                                // message on this connection.
                                down = true;
                                break;
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            down = true;
                            break;
                        }
                    }
                }
            }
            for msg in msgs {
                match msg {
                    BackendMsg::Line(line) => {
                        self.app.on_backend_line(&mut self.core, id, line);
                    }
                    BackendMsg::Frame(frame) => {
                        self.app.on_backend_frame(&mut self.core, id, frame);
                    }
                }
            }
            if down {
                self.backend_down(id);
            }
        }
    }

    /// Enforce the connect and IO deadlines the blocking relay enforced
    /// with socket timeouts: a backend stuck connecting, or silent while
    /// it owes responses, is declared down (and its requests fail over).
    /// A backend that stops *draining* is bounded the same way: a
    /// pending-write queue past [`MAX_BACKEND_OUTBUF`] means it is not
    /// keeping up, and waiting the full IO deadline would let the queue
    /// grow at ingest rate — fail it over instead.
    fn sweep_backend_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .core
            .backends
            .iter()
            .filter(|(_, b)| {
                (b.connecting && now.duration_since(b.opened) > BACKEND_CONNECT_TIMEOUT)
                    || (!b.connecting
                        && b.awaiting > 0
                        && now.duration_since(b.last_activity) > BACKEND_IO_TIMEOUT)
                    || (!b.connecting && b.out.len() > MAX_BACKEND_OUTBUF)
            })
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            self.backend_down(id);
        }
    }

    /// Inbound twin of [`sweep_backend_deadlines`]: a connection silent
    /// past the idle deadline with nothing in flight is closed. In-flight
    /// work exempts a connection — slow *responses* are the server's
    /// fault, not the client's.
    fn sweep_client_deadlines(&mut self) {
        let idle = self.core.front.idle_timeout;
        if idle.is_zero() {
            return;
        }
        let now = Instant::now();
        let mut closed = 0u64;
        for c in self.core.conns.values_mut() {
            if !c.dead && c.quiescent() && now.duration_since(c.last_activity) > idle {
                c.dead = true;
                closed += 1;
            }
        }
        if closed > 0 {
            self.app
                .metrics()
                .lock()
                .expect("metrics lock")
                .incr("clients_idle_closed", closed);
        }
    }

    fn backend_down(&mut self, id: u64) {
        if self.core.backends.remove(&id).is_some() {
            self.app.on_backend_down(&mut self.core, id);
        }
    }

    fn drain_completions(&mut self) {
        while let Ok((id, seq, line)) = self.core.completions_rx.try_recv() {
            self.core.complete(id, seq, line);
        }
    }

    fn flush_conns(&mut self) {
        let mut errors = 0u64;
        for (&id, conn) in self.core.conns.iter_mut() {
            if conn.dead {
                continue;
            }
            // Release contiguously-completed responses, in request order.
            // Each payload writes its own framing (newline for JSON lines,
            // nothing extra for binary frames) — one buffered write, no
            // re-encode, regardless of protocol.
            while let Some(payload) = conn.ready.remove(&conn.emit_seq) {
                payload.write_wire(&mut conn.out);
                conn.emit_seq += 1;
            }
            if conn.out.is_empty() {
                continue;
            }
            let traced = obs::enabled();
            let t0 = if traced { obs::now_us() } else { 0 };
            if !flush_bytes(&conn.stream, &mut conn.out, faults::Site::ClientWrite) {
                errors += 1;
                conn.dead = true;
            }
            if traced {
                let dur = obs::now_us().saturating_sub(t0) as f64;
                obs::record_conn(id, self.core.front.service, Stage::Write, t0, dur);
            }
        }
        if errors > 0 {
            self.app
                .metrics()
                .lock()
                .expect("metrics lock")
                .incr("connection_errors", errors);
        }
    }
}

/// One complete message framed off a backend connection's byte stream.
enum BackendMsg {
    /// A newline-terminated JSON line (terminator stripped, trimmed).
    Line(String),
    /// A complete binary frame, header included — verbatim wire bytes.
    Frame(Vec<u8>),
}

/// Split every complete message off the front of `b.inbuf` — backends mix
/// newline-framed lines and magic-prefixed binary frames freely, exactly
/// like clients (a message opening with the 4-byte frame magic is binary;
/// fewer matching bytes than the magic is an ambiguous prefix that waits
/// for more). `Err` means a message exceeded [`MAX_RESPONSE_BYTES`] and
/// the connection can no longer be framed.
fn drain_backend_msgs(b: &mut BackendConn, msgs: &mut Vec<BackendMsg>) -> Result<(), ()> {
    loop {
        if b.inbuf.is_empty() {
            return Ok(());
        }
        let m = b.inbuf.len().min(FRAME_MAGIC.len());
        if b.inbuf[..m] == FRAME_MAGIC[..m] {
            if b.inbuf.len() < FRAME_HEADER {
                // Ambiguous (partial magic) or incomplete header: no line
                // terminator can hide in these bytes, so the scan cursor
                // may safely skip them if the prefix later diverges.
                b.scanned = b.inbuf.len();
                return Ok(());
            }
            let len = u32::from_le_bytes(b.inbuf[4..8].try_into().expect("4 bytes")) as usize;
            if len > MAX_RESPONSE_BYTES {
                return Err(());
            }
            let total = FRAME_HEADER + len;
            if b.inbuf.len() < total {
                return Ok(());
            }
            let frame: Vec<u8> = b.inbuf.drain(..total).collect();
            b.scanned = 0;
            b.awaiting = b.awaiting.saturating_sub(1);
            msgs.push(BackendMsg::Frame(frame));
            continue;
        }
        // Line framing: scan only bytes not already searched — the cursor
        // survives partial reads, so framing a response that arrives in
        // many chunks stays linear instead of rescanning from byte 0.
        match b.inbuf[b.scanned..].iter().position(|&x| x == b'\n') {
            Some(rel) => {
                let pos = b.scanned + rel;
                let taken: Vec<u8> = b.inbuf.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&taken[..pos]).trim_end().to_string();
                b.scanned = 0;
                b.awaiting = b.awaiting.saturating_sub(1);
                msgs.push(BackendMsg::Line(line));
            }
            None => {
                b.scanned = b.inbuf.len();
                if b.inbuf.len() > MAX_RESPONSE_BYTES {
                    return Err(());
                }
                return Ok(());
            }
        }
    }
}

/// Write as much of `out` as the socket takes, draining written bytes.
/// Returns `false` when the connection is dead (hard error or EOF-write).
/// `site` is the fault-injection seam: a `short_write` decision caps this
/// round at a prefix of the buffer — the remainder stays queued, exactly
/// the partial-write shape a full socket produces, so correctness must
/// not depend on a line leaving in one `write(2)`.
fn flush_bytes(stream: &TcpStream, out: &mut Vec<u8>, site: faults::Site) -> bool {
    let mut limit = out.len();
    if faults::enabled() {
        match faults::decide(site) {
            faults::Fault::ShortWrite => {
                limit = faults::short_write_len(out.len()).min(out.len());
            }
            faults::Fault::Stall(d) => std::thread::sleep(d),
            _ => {}
        }
    }
    let mut written = 0usize;
    let mut alive = true;
    while written < limit {
        match (&*stream).write(&out[written..limit]) {
            Ok(0) => {
                alive = false;
                break;
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                alive = false;
                break;
            }
        }
    }
    out.drain(..written);
    alive
}

// --------------------------------------------------------------- serve app --

/// The `goomd` instantiation: decoded requests dispatch into the worker
/// pool (introspection and cache hits answer inline); completions return
/// through the reactor's reply channel. No backend connections.
pub struct ServeApp {
    pub inner: Arc<ServerInner>,
    pub pool: Arc<Pool<Job>>,
    /// This reactor's private stats block (registered in the server's
    /// [`ReactorSet`] — one per loop of a sharded front).
    pub stats: Arc<ReactorStats>,
}

impl App for ServeApp {
    fn front(&self) -> FrontConfig {
        FrontConfig {
            service: "server",
            max_request_bytes: self.inner.cfg.max_request_bytes,
            max_connections: self.inner.cfg.max_connections,
            retry_after_ms: self.inner.cfg.retry_after_ms,
            idle_timeout: Duration::from_secs(self.inner.cfg.idle_timeout_s),
        }
    }

    fn metrics(&self) -> &Mutex<Metrics> {
        &self.inner.metrics
    }

    fn stats(&self) -> Arc<ReactorStats> {
        Arc::clone(&self.stats)
    }

    fn on_request(
        &mut self,
        core: &mut Core,
        conn: u64,
        seq: u64,
        req: Request,
        ctx: ReqCtx,
        wire: Wire,
    ) {
        let conn_inflight = core.conn_inflight(conn);
        let sink = core.reply_to(conn, seq);
        dispatch(req, ctx, &self.inner, &self.pool, conn_inflight, wire, sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reactor_stats_export_and_high_water_reorder_depth() {
        let stats = ReactorStats::default();
        stats.loop_iterations.fetch_add(3, Ordering::Relaxed);
        stats.raise_reorder_depth(4);
        stats.raise_reorder_depth(2); // lower: must not regress the mark
        let doc = stats.to_json();
        let keys =
            ["loop_iterations", "wakeups", "fds_accepted", "fds_connected", "max_reorder_depth"];
        for key in keys {
            assert!(doc.get(key).is_some(), "missing {key}");
        }
        assert_eq!(doc.get("loop_iterations").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("max_reorder_depth").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn reactor_set_rolls_up_sums_and_reorder_peak() {
        let set = ReactorSet::default();
        let a = set.register();
        let b = set.register();
        a.loop_iterations.fetch_add(5, Ordering::Relaxed);
        b.loop_iterations.fetch_add(7, Ordering::Relaxed);
        a.fds_accepted.fetch_add(2, Ordering::Relaxed);
        b.fds_accepted.fetch_add(3, Ordering::Relaxed);
        a.raise_reorder_depth(9); // the peak is a max across loops, not a sum
        b.raise_reorder_depth(4);
        let doc = set.to_json();
        assert_eq!(doc.get("loop_iterations").unwrap().as_usize(), Some(12));
        assert_eq!(doc.get("fds_accepted").unwrap().as_usize(), Some(5));
        assert_eq!(doc.get("max_reorder_depth").unwrap().as_usize(), Some(9));
        assert_eq!(doc.get("reactors").unwrap().as_usize(), Some(2));
        match doc.get("per_reactor") {
            Some(Json::Arr(blocks)) => {
                assert_eq!(blocks.len(), 2);
                assert_eq!(blocks[0].get("loop_iterations").unwrap().as_usize(), Some(5));
                assert_eq!(blocks[1].get("fds_accepted").unwrap().as_usize(), Some(3));
            }
            other => panic!("per_reactor missing or not an array: {other:?}"),
        }
    }

    #[test]
    fn nonblocking_connect_reports_refusal_not_hang() {
        // A bound-then-dropped port refuses connections: the non-blocking
        // connect must either fail immediately or resolve the refusal via
        // take_error after the in-progress phase — never block the caller.
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let t0 = Instant::now();
        match connect_nonblocking(&port) {
            Err(_) => {}
            Ok((stream, connecting)) => {
                if connecting {
                    // Refusal arrives asynchronously; poll-free check with
                    // a short grace period.
                    let mut refused = false;
                    for _ in 0..200 {
                        match stream.take_error() {
                            Ok(Some(_)) | Err(_) => {
                                refused = true;
                                break;
                            }
                            Ok(None) => std::thread::sleep(Duration::from_millis(5)),
                        }
                    }
                    assert!(refused, "refused connect never surfaced an error");
                }
            }
        }
        assert!(
            t0.elapsed() < BACKEND_CONNECT_TIMEOUT + Duration::from_secs(2),
            "connect path blocked too long"
        );
    }
}
