//! Readiness event loop: N connections, O(1) threads.
//!
//! The pre-refactor daemon spent one OS thread (and stack) per live
//! connection. This module replaces that with a single loop thread driving
//! every connection's [`SessionState`] over non-blocking sockets: `poll(2)`
//! (declared directly against the C library std already links — no new
//! dependencies) reports which sockets are readable/writable, the loop
//! feeds bytes through the sans-IO machines, and compute responses arrive
//! asynchronously from pool workers over a completion channel paired with
//! a self-pipe waker. 1k idle connections now cost 1k file descriptors,
//! not 1k stacks; the thread set is fixed (loop + workers) regardless of
//! connection count.
//!
//! Response ordering: the protocol is strictly request-order per
//! connection, but the loop pipelines — a connection's later requests can
//! decode (and even complete) while an earlier compute is still in the
//! pool. Each request takes a sequence number; finished lines park in a
//! per-connection reorder buffer and flush only in sequence.
//!
//! On non-unix hosts a portable fallback ticks every couple of
//! milliseconds and treats every socket as ready — spurious readiness
//! costs one `WouldBlock` per socket, correctness is unchanged.

use super::inflight::Reply;
use super::pool::Pool;
use super::protocol::err_line;
use super::session::{dispatch, Job, ServerInner, SessionEvent, SessionState};
use std::collections::{BTreeMap, HashMap};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// Bytes read per `read(2)` call.
const READ_CHUNK: usize = 64 * 1024;
/// Stop reading from a connection whose un-flushed output exceeds this
/// (the client isn't draining responses; don't buffer for it unboundedly).
const MAX_OUTBUF: usize = 4 << 20;
/// Poll timeout: an upper bound on shutdown latency, not a serving rate —
/// I/O and completions wake the loop immediately.
const POLL_TIMEOUT_MS: i32 = 500;

/// A finished response line for connection `.0`, request slot `.1`.
type Completion = (u64, u64, String);

/// Wakes the loop out of `poll` from worker threads (self-pipe trick).
pub struct Waker {
    #[cfg(unix)]
    tx: std::os::unix::net::UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            // One byte is enough; WouldBlock means a wake is already queued.
            let _ = (&self.tx).write(&[1u8]);
        }
    }
}

#[cfg(unix)]
fn waker_pair() -> io::Result<(Waker, std::os::unix::net::UnixStream)> {
    let (rx, tx) = std::os::unix::net::UnixStream::pair()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((Waker { tx }, rx))
}

#[cfg(unix)]
mod sys {
    //! The one C declaration the loop needs. std links libc on every unix
    //! target, so this adds no dependency — just a prototype.
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    // nfds_t is `unsigned long` on Linux (pointer-width) and `unsigned
    // int` on the BSD family — match the ABI, not just the OS name.
    #[cfg(all(target_os = "linux", target_pointer_width = "64"))]
    pub type Nfds = u64;
    #[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
    pub type Nfds = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
    }
}

/// One live connection: its socket, protocol state, and the reorder buffer
/// that keeps pipelined responses in request order.
struct Conn {
    stream: TcpStream,
    session: SessionState,
    /// Bytes framed and waiting for the socket to accept them.
    out: Vec<u8>,
    /// Next request slot to assign.
    next_seq: u64,
    /// Next slot whose response may be flushed.
    emit_seq: u64,
    /// Completed lines waiting on earlier slots.
    ready: BTreeMap<u64, String>,
    read_closed: bool,
    dead: bool,
    readable: bool,
}

impl Conn {
    fn finished(&self) -> bool {
        self.read_closed && self.emit_seq == self.next_seq && self.out.is_empty()
    }
}

/// Start the loop thread. The returned [`Waker`] interrupts `poll` — used
/// by job completions and by [`super::Server::stop`].
pub fn spawn(
    listener: TcpListener,
    inner: Arc<ServerInner>,
    pool: Arc<Pool<Job>>,
    shutdown: Arc<AtomicBool>,
) -> io::Result<(JoinHandle<()>, Arc<Waker>)> {
    #[cfg(unix)]
    let (waker, wake_rx) = waker_pair()?;
    #[cfg(not(unix))]
    let waker = Waker {};
    let waker = Arc::new(waker);
    let loop_waker = Arc::clone(&waker);
    let handle = std::thread::Builder::new()
        .name("goomd-eventloop".to_string())
        .spawn(move || {
            let (tx, rx) = mpsc::channel::<Completion>();
            EventLoop {
                listener,
                inner,
                pool,
                shutdown,
                waker: loop_waker,
                #[cfg(unix)]
                wake_rx,
                completions_tx: tx,
                completions_rx: rx,
                conns: HashMap::new(),
                next_conn_id: 0,
                listener_ready: false,
            }
            .run();
        })?;
    Ok((handle, waker))
}

struct EventLoop {
    listener: TcpListener,
    inner: Arc<ServerInner>,
    pool: Arc<Pool<Job>>,
    shutdown: Arc<AtomicBool>,
    waker: Arc<Waker>,
    #[cfg(unix)]
    wake_rx: std::os::unix::net::UnixStream,
    completions_tx: mpsc::Sender<Completion>,
    completions_rx: mpsc::Receiver<Completion>,
    conns: HashMap<u64, Conn>,
    next_conn_id: u64,
    listener_ready: bool,
}

impl EventLoop {
    fn run(mut self) {
        loop {
            self.wait_ready();
            if self.shutdown.load(Ordering::SeqCst) {
                // Best-effort final pass: pool teardown has just resolved
                // queued jobs with shutdown-error lines — deliver what the
                // sockets will take before closing them.
                self.drain_completions();
                self.flush_conns();
                return;
            }
            self.accept_ready();
            self.read_ready();
            self.drain_completions();
            self.flush_conns();
            self.conns.retain(|_, c| !c.dead && !c.finished());
        }
    }

    /// Block until something needs service (or the poll timeout elapses):
    /// a new connection, readable/writable sockets, or a waker byte from a
    /// completed job.
    #[cfg(unix)]
    fn wait_ready(&mut self) {
        use std::os::unix::io::AsRawFd;

        let mut fds: Vec<sys::PollFd> = Vec::with_capacity(self.conns.len() + 2);
        let mut tokens: Vec<Option<u64>> = Vec::with_capacity(self.conns.len() + 2);
        fds.push(sys::PollFd {
            fd: self.listener.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        tokens.push(None);
        fds.push(sys::PollFd {
            fd: self.wake_rx.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        tokens.push(None);
        for (&id, conn) in &mut self.conns {
            conn.readable = false;
            let mut events = 0i16;
            if !conn.read_closed && conn.out.len() <= MAX_OUTBUF {
                events |= sys::POLLIN;
            }
            if !conn.out.is_empty() {
                events |= sys::POLLOUT;
            }
            if events == 0 {
                continue;
            }
            fds.push(sys::PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
            tokens.push(Some(id));
        }
        let n = unsafe {
            sys::poll(fds.as_mut_ptr(), fds.len() as sys::Nfds, POLL_TIMEOUT_MS)
        };
        self.listener_ready = false;
        if n < 0 {
            if io::Error::last_os_error().kind() != io::ErrorKind::Interrupted {
                // Not expected; avoid a hot error spin.
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            return;
        }
        self.listener_ready = fds[0].revents != 0;
        if fds[1].revents != 0 {
            // Swallow queued wake bytes; completions drain separately.
            let mut sink = [0u8; 256];
            while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }
        for (fd, token) in fds.iter().zip(&tokens).skip(2) {
            let hang = fd.revents & (sys::POLLERR | sys::POLLHUP) != 0;
            if fd.revents & sys::POLLIN != 0 || hang {
                if let Some(conn) =
                    token.as_ref().and_then(|id| self.conns.get_mut(id))
                {
                    // A hangup on a read-closed conn is surfaced by the
                    // flush path instead.
                    conn.readable = !conn.read_closed;
                }
            }
        }
    }

    /// Portable fallback: tick and treat everything as ready. Non-blocking
    /// sockets make spurious readiness harmless (one `WouldBlock` each).
    #[cfg(not(unix))]
    fn wait_ready(&mut self) {
        std::thread::sleep(std::time::Duration::from_millis(2));
        self.listener_ready = true;
        for conn in self.conns.values_mut() {
            conn.readable = !conn.read_closed && conn.out.len() <= MAX_OUTBUF;
        }
    }

    fn accept_ready(&mut self) {
        if !self.listener_ready {
            return;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => self.on_accept(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    // e.g. EMFILE: the pending connection stays in the
                    // backlog, so poll would report the listener readable
                    // again immediately — back off briefly instead of
                    // spinning the loop at 100% CPU.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn on_accept(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return; // drops (closes) the stream
        }
        let max_connections = self.inner.cfg.max_connections.max(1);
        if self.conns.len() >= max_connections {
            self.inner
                .metrics
                .lock()
                .expect("metrics lock")
                .incr("connections_rejected", 1);
            let mut line = err_line(
                &format!(
                    "server busy: connection limit ({max_connections}) reached"
                ),
                Some(self.inner.cfg.retry_after_ms),
            );
            line.push('\n');
            // Best-effort: a fresh socket's send buffer is empty, so this
            // short line fits or the client is already gone.
            let _ = (&stream).write(line.as_bytes());
            return; // drops (closes) the stream
        }
        self.inner.metrics.lock().expect("metrics lock").incr("connections", 1);
        let id = self.next_conn_id;
        self.next_conn_id += 1;
        self.conns.insert(
            id,
            Conn {
                stream,
                session: SessionState::new(self.inner.cfg.max_request_bytes),
                out: Vec::new(),
                next_seq: 0,
                emit_seq: 0,
                ready: BTreeMap::new(),
                read_closed: false,
                dead: false,
                // Serve bytes that raced ahead of the first poll.
                readable: true,
            },
        );
    }

    fn read_ready(&mut self) {
        let ids: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.readable && !c.dead && !c.read_closed)
            .map(|(&id, _)| id)
            .collect();
        let mut buf = vec![0u8; READ_CHUNK];
        for id in ids {
            let mut events = Vec::new();
            let conn = self.conns.get_mut(&id).expect("conn exists");
            // Fairness budget: one firehosing client must not pin the loop;
            // leftover bytes stay in the kernel buffer and poll reports the
            // socket readable again next iteration.
            let mut budget = 16;
            loop {
                if budget == 0 {
                    break;
                }
                budget -= 1;
                match (&conn.stream).read(&mut buf) {
                    Ok(0) => {
                        conn.session.on_eof(&mut events);
                        break;
                    }
                    Ok(n) => {
                        conn.session.on_bytes(&buf[..n], &mut events);
                        if conn.session.is_closed() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        self.inner
                            .metrics
                            .lock()
                            .expect("metrics lock")
                            .incr("connection_errors", 1);
                        conn.dead = true;
                        break;
                    }
                }
            }
            self.handle_events(id, events);
        }
    }

    fn handle_events(&mut self, id: u64, events: Vec<SessionEvent>) {
        for ev in events {
            match ev {
                SessionEvent::Request(req) => {
                    self.inner
                        .metrics
                        .lock()
                        .expect("metrics lock")
                        .incr("requests_total", 1);
                    let seq = self.assign_seq(id);
                    let reply = self.reply_to(id, seq);
                    dispatch(req, &self.inner, &self.pool, reply);
                }
                SessionEvent::BadLine(line) => {
                    self.inner
                        .metrics
                        .lock()
                        .expect("metrics lock")
                        .incr("requests_total", 1);
                    let seq = self.assign_seq(id);
                    self.complete(id, seq, line);
                }
                SessionEvent::Oversized(line) => {
                    self.inner
                        .metrics
                        .lock()
                        .expect("metrics lock")
                        .incr("oversized_rejects", 1);
                    let seq = self.assign_seq(id);
                    self.complete(id, seq, line);
                }
                SessionEvent::Close => {
                    if let Some(c) = self.conns.get_mut(&id) {
                        c.read_closed = true;
                    }
                }
            }
        }
    }

    fn assign_seq(&mut self, id: u64) -> u64 {
        let c = self.conns.get_mut(&id).expect("conn exists");
        let seq = c.next_seq;
        c.next_seq += 1;
        seq
    }

    /// The [`Reply`] for request slot (`id`, `seq`): routes the finished
    /// line back through the completion channel and wakes the loop. Works
    /// from any thread; a reply for a since-closed connection is dropped.
    fn reply_to(&self, id: u64, seq: u64) -> Reply {
        let tx = self.completions_tx.clone();
        let waker = Arc::clone(&self.waker);
        Box::new(move |line| {
            let _ = tx.send((id, seq, line));
            waker.wake();
        })
    }

    fn complete(&mut self, id: u64, seq: u64, line: String) {
        if let Some(c) = self.conns.get_mut(&id) {
            c.ready.insert(seq, line);
        }
    }

    fn drain_completions(&mut self) {
        while let Ok((id, seq, line)) = self.completions_rx.try_recv() {
            self.complete(id, seq, line);
        }
    }

    fn flush_conns(&mut self) {
        for conn in self.conns.values_mut() {
            if conn.dead {
                continue;
            }
            // Release contiguously-completed responses, in request order.
            while let Some(line) = conn.ready.remove(&conn.emit_seq) {
                conn.out.extend_from_slice(line.as_bytes());
                conn.out.push(b'\n');
                conn.emit_seq += 1;
            }
            if conn.out.is_empty() {
                continue;
            }
            let mut written = 0usize;
            while written < conn.out.len() {
                match (&conn.stream).write(&conn.out[written..]) {
                    Ok(0) => {
                        conn.dead = true;
                        break;
                    }
                    Ok(n) => written += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        self.inner
                            .metrics
                            .lock()
                            .expect("metrics lock")
                            .incr("connection_errors", 1);
                        conn.dead = true;
                        break;
                    }
                }
            }
            conn.out.drain(..written);
        }
    }
}
