//! Admission control for the serving tiers.
//!
//! Both reactor apps consult one [`Admission`] before accepting work:
//! `ServeApp` (the compute shard) checks all three policies, `RelayApp`
//! (the router) checks per-connection fairness — work executes on the
//! shards, so that is where cost accounting lives. A sharded front
//! (`--reactors=N`) shares a single `Arc<Admission>` across all of its
//! reactors: the state is entirely atomics, so every loop thread consults
//! and releases it lock-free, and the work budget / fairness policy
//! stays a property of the process, not of one loop.
//!
//! Three policies, all cheap enough for the reactor thread:
//!
//! * **Adaptive shedding** — when work is turned away, the `retry_after_ms`
//!   hint is no longer the static config value but the *observed* time to
//!   drain the current queue: `queue_len × mean(stage_exec) / workers`,
//!   clamped to `[retry_after_ms, max_retry_after_ms]`. A client shedding
//!   against a deep queue of slow jobs is told to come back later than one
//!   shedding against a nearly-drained queue — so retries land when they
//!   can be served instead of re-stampeding.
//! * **Per-client fairness** — each connection gets an in-flight cap
//!   (`--inflight-per-conn`). Under queue pressure the cap *tightens*
//!   linearly (full cap at ≤50% queue, down to 1 at 100%), so the
//!   heaviest pipeliners shed first and one `--pipeline=N` client cannot
//!   starve lockstep clients out of the queue.
//! * **Cost-aware admission** — requests are charged in the PR-4 work
//!   currency (`d³·steps`, [`crate::server::protocol::Request::work_units`])
//!   against a total outstanding-work budget, so one `d=1024` chain at the
//!   budget ceiling is charged honestly as the ~400 small-chain equivalents
//!   it is, instead of as one queue slot.
//!
//! Shed decisions never corrupt: a shed is always a well-formed
//! `{"ok":false,...,"retry_after_ms":…}` line, and the loadgen client
//! backs off and retries. Policy rationale in `docs/RELIABILITY.md`.

use crate::coordinator::Metrics;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Knobs, layered like every other serve config: defaults < `repro.conf`
/// < CLI flags.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Per-connection in-flight cap (0 disables fairness shedding).
    pub inflight_per_conn: usize,
    /// Total outstanding-work budget in `d³·steps` units. Defaults to
    /// 8 × the single-request ceiling ([`crate::server::protocol::MAX_CHAIN_WORK`]).
    pub work_capacity: u64,
    /// Floor for the dynamic retry hint — the pre-admission static value.
    pub base_retry_ms: u64,
    /// Ceiling for the dynamic retry hint.
    pub max_retry_ms: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            inflight_per_conn: 64,
            work_capacity: (crate::server::protocol::MAX_CHAIN_WORK as u64)
                .saturating_mul(8),
            base_retry_ms: 100,
            max_retry_ms: 5_000,
        }
    }
}

/// Shared admission state. All atomics — safe to consult from the reactor
/// thread and release from pool workers without a lock.
#[derive(Debug)]
pub struct Admission {
    cfg: AdmissionConfig,
    /// Work units currently admitted but not yet resolved.
    outstanding: AtomicU64,
    /// Last dynamic retry hint handed out (exported as a gauge).
    last_retry_ms: AtomicU64,
    shed_fairness: AtomicU64,
    shed_cost: AtomicU64,
    shed_queue: AtomicU64,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        let base = cfg.base_retry_ms;
        Self {
            cfg,
            outstanding: AtomicU64::new(0),
            last_retry_ms: AtomicU64::new(base),
            shed_fairness: AtomicU64::new(0),
            shed_cost: AtomicU64::new(0),
            shed_queue: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// The dynamic `retry_after_ms` hint: expected time for `workers` to
    /// drain `queue_len` jobs at the observed mean execution time. Falls
    /// back to the static floor until `stage_exec` has samples.
    pub fn retry_after_ms(
        &self,
        queue_len: usize,
        workers: usize,
        metrics: &Metrics,
    ) -> u64 {
        let ms = match metrics.timer_mean("stage_exec") {
            Some(mean_s) if mean_s > 0.0 => {
                let drain_s =
                    mean_s * (queue_len.max(1) as f64) / (workers.max(1) as f64);
                (drain_s * 1e3).ceil() as u64
            }
            _ => self.cfg.base_retry_ms,
        };
        let ms = ms.clamp(self.cfg.base_retry_ms.max(1), self.cfg.max_retry_ms.max(1));
        self.last_retry_ms.store(ms, Ordering::Relaxed);
        ms
    }

    /// The effective per-connection in-flight cap at the current queue
    /// pressure: the configured cap while the queue is under half full,
    /// tightening linearly to 1 as it fills — weighted shedding, heaviest
    /// pipeliners first.
    pub fn fair_cap(&self, queue_len: usize, queue_depth: usize) -> usize {
        let cap = self.cfg.inflight_per_conn;
        if cap == 0 {
            return usize::MAX;
        }
        let pressure = queue_len as f64 / queue_depth.max(1) as f64;
        if pressure <= 0.5 {
            return cap;
        }
        let scale = ((1.0 - pressure) * 2.0).clamp(0.0, 1.0);
        ((cap as f64 * scale).floor() as usize).max(1)
    }

    /// Fairness check for one more request on a connection already holding
    /// `conn_inflight`. `false` means shed (tallied).
    pub fn admit_conn(
        &self,
        conn_inflight: usize,
        queue_len: usize,
        queue_depth: usize,
    ) -> bool {
        if conn_inflight < self.fair_cap(queue_len, queue_depth) {
            true
        } else {
            self.shed_fairness.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// Cost check: reserve `work` units against the outstanding budget.
    /// On success the caller owns the reservation and must [`release`]
    /// it when the request resolves (any path — success, error, shed
    /// downstream). An idle controller always admits, so a request is
    /// never unservable no matter how the capacity is (mis)configured.
    pub fn try_reserve(&self, work: u64) -> bool {
        let mut cur = self.outstanding.load(Ordering::Relaxed);
        loop {
            if cur != 0 && cur.saturating_add(work) > self.cfg.work_capacity {
                self.shed_cost.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match self.outstanding.compare_exchange_weak(
                cur,
                cur.saturating_add(work),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Return a reservation made by [`try_reserve`].
    pub fn release(&self, work: u64) {
        let _ = self.outstanding.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| Some(cur.saturating_sub(work)),
        );
    }

    pub fn outstanding_work(&self) -> u64 {
        self.outstanding.load(Ordering::Relaxed)
    }

    /// Tally a queue-full shed (the bounded pool turned the job away).
    pub fn note_queue_shed(&self) {
        self.shed_queue.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shed_total(&self) -> u64 {
        self.shed_fairness.load(Ordering::Relaxed)
            + self.shed_cost.load(Ordering::Relaxed)
            + self.shed_queue.load(Ordering::Relaxed)
    }

    /// The `"admission"` section of the `metrics` op.
    pub fn to_json(&self, queue_len: usize, queue_depth: usize) -> Json {
        let mut m = BTreeMap::new();
        let n = |x: u64| Json::Num(x as f64);
        m.insert("outstanding_work".to_string(), n(self.outstanding_work()));
        m.insert("work_capacity".to_string(), n(self.cfg.work_capacity));
        m.insert(
            "inflight_per_conn".to_string(),
            n(self.cfg.inflight_per_conn as u64),
        );
        m.insert(
            "fair_cap_now".to_string(),
            Json::Num(match self.fair_cap(queue_len, queue_depth) {
                usize::MAX => -1.0,
                cap => cap as f64,
            }),
        );
        m.insert(
            "retry_after_ms_last".to_string(),
            n(self.last_retry_ms.load(Ordering::Relaxed)),
        );
        m.insert(
            "shed_fairness".to_string(),
            n(self.shed_fairness.load(Ordering::Relaxed)),
        );
        m.insert("shed_cost".to_string(), n(self.shed_cost.load(Ordering::Relaxed)));
        m.insert(
            "shed_queue_full".to_string(),
            n(self.shed_queue.load(Ordering::Relaxed)),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(cfg: AdmissionConfig) -> Admission {
        Admission::new(cfg)
    }

    #[test]
    fn retry_hint_falls_back_to_the_static_floor_without_samples() {
        let a = adm(AdmissionConfig { base_retry_ms: 100, ..Default::default() });
        let m = Metrics::new();
        assert_eq!(a.retry_after_ms(10, 2, &m), 100);
    }

    #[test]
    fn retry_hint_scales_with_queue_depth_and_drain_rate() {
        let a = adm(AdmissionConfig {
            base_retry_ms: 10,
            max_retry_ms: 60_000,
            ..Default::default()
        });
        let mut m = Metrics::new();
        // 20 ms mean execution per job.
        for _ in 0..32 {
            m.record_secs("stage_exec", 0.020);
        }
        // 40 queued / 2 workers × 20 ms = 400 ms to drain.
        let hint = a.retry_after_ms(40, 2, &m);
        assert!((380..=440).contains(&hint), "hint {hint}");
        // A short queue drains fast: clamps to the floor.
        assert_eq!(a.retry_after_ms(0, 2, &m), 10);
        // The ceiling clamps pathological queues.
        let a = adm(AdmissionConfig {
            base_retry_ms: 10,
            max_retry_ms: 500,
            ..Default::default()
        });
        assert_eq!(a.retry_after_ms(100_000, 1, &m), 500);
    }

    #[test]
    fn fair_cap_tightens_under_pressure() {
        let a = adm(AdmissionConfig { inflight_per_conn: 32, ..Default::default() });
        assert_eq!(a.fair_cap(0, 64), 32, "idle queue: full cap");
        assert_eq!(a.fair_cap(32, 64), 32, "half full: still full cap");
        assert_eq!(a.fair_cap(48, 64), 16, "75% full: half cap");
        assert_eq!(a.fair_cap(64, 64), 1, "full queue: cap of 1");
        // Cap 0 disables fairness entirely.
        let a = adm(AdmissionConfig { inflight_per_conn: 0, ..Default::default() });
        assert_eq!(a.fair_cap(64, 64), usize::MAX);
        assert!(a.admit_conn(1_000_000, 64, 64));
    }

    #[test]
    fn fairness_sheds_the_heavy_pipeliner_not_the_lockstep_client() {
        let a = adm(AdmissionConfig { inflight_per_conn: 8, ..Default::default() });
        // At 75% pressure the cap is 4: a client with 6 in flight sheds,
        // a lockstep client with 0 in flight still gets through.
        assert!(!a.admit_conn(6, 48, 64));
        assert!(a.admit_conn(0, 48, 64));
        assert_eq!(a.shed_total(), 1);
    }

    #[test]
    fn cost_budget_charges_big_chains_honestly() {
        let a = adm(AdmissionConfig { work_capacity: 1_000, ..Default::default() });
        assert!(a.try_reserve(600));
        assert!(a.try_reserve(400));
        assert_eq!(a.outstanding_work(), 1_000);
        // Budget exhausted: the next unit sheds.
        assert!(!a.try_reserve(1));
        a.release(400);
        assert!(a.try_reserve(300));
        a.release(600);
        a.release(300);
        assert_eq!(a.outstanding_work(), 0);
        // Releasing more than reserved saturates at zero, never wraps.
        a.release(1);
        assert_eq!(a.outstanding_work(), 0);
    }

    #[test]
    fn an_idle_controller_always_admits() {
        // Even a request bigger than the whole budget is admitted when
        // nothing is outstanding — no request is permanently unservable.
        let a = adm(AdmissionConfig { work_capacity: 10, ..Default::default() });
        assert!(a.try_reserve(1_000));
        assert!(!a.try_reserve(1));
        a.release(1_000);
    }

    #[test]
    fn json_section_reports_state_and_tallies() {
        let a = adm(AdmissionConfig {
            inflight_per_conn: 8,
            work_capacity: 100,
            ..Default::default()
        });
        assert!(a.try_reserve(40));
        assert!(!a.admit_conn(100, 0, 64));
        a.note_queue_shed();
        let j = a.to_json(0, 64);
        assert_eq!(j.get("outstanding_work").unwrap().as_f64(), Some(40.0));
        assert_eq!(j.get("work_capacity").unwrap().as_f64(), Some(100.0));
        assert_eq!(j.get("fair_cap_now").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("shed_fairness").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("shed_queue_full").unwrap().as_f64(), Some(1.0));
        assert_eq!(a.shed_total(), 2);
    }
}
