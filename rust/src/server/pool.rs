//! Persistent worker pool with a bounded job queue, backpressure, and
//! same-key batch draining.
//!
//! Served traffic must not spawn threads per request (`std::thread::scope`
//! per call is fine for one-shot experiments, fatal for a daemon): the pool
//! starts `workers` OS threads once and feeds them from a bounded
//! `VecDeque`. When the queue is full, [`Pool::try_submit`] rejects
//! immediately — the session layer turns that into a `retry_after_ms`
//! response instead of letting latency collapse under overload.
//!
//! Batching: when a worker pops a job whose `batch_key` is `Some(k)`, it
//! also drains every other queued job with the same key (up to
//! `batch_max`), handing the whole group to the executor in one call. The
//! server uses this to fold concurrent same-shape GOOM chain requests —
//! and same-dimension scan requests — into stacked LMME passes
//! ([`crate::goom::lmme_batched`]).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Why a submission was rejected; the job is handed back so its reply
/// channel can carry the rejection to the client.
#[derive(Debug)]
pub enum SubmitError<J> {
    /// Queue at capacity — shed load, ask the client to retry.
    Full(J),
    /// Pool is shutting down.
    Shutdown(J),
}

struct QueueState<J> {
    queue: VecDeque<J>,
    shutdown: bool,
}

struct Shared<J> {
    state: Mutex<QueueState<J>>,
    available: Condvar,
    depth: usize,
    batch_max: usize,
}

/// The worker pool. Generic over the job type; the batch-key and executor
/// closures are fixed at construction.
pub struct Pool<J: Send + 'static> {
    shared: Arc<Shared<J>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl<J: Send + 'static> Pool<J> {
    /// Start `workers` threads (min 1). `queue_depth` bounds jobs *waiting*
    /// (jobs being executed don't count). `batch_max` caps how many
    /// same-key jobs one executor call may receive (min 1).
    pub fn new<K, E>(
        workers: usize,
        queue_depth: usize,
        batch_max: usize,
        batch_key: K,
        exec: E,
    ) -> Self
    where
        K: Fn(&J) -> Option<String> + Send + Sync + 'static,
        E: Fn(Vec<J>) + Send + Sync + 'static,
    {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { queue: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            depth: queue_depth.max(1),
            batch_max: batch_max.max(1),
        });
        let batch_key = Arc::new(batch_key);
        let exec = Arc::new(exec);
        let handles = (0..workers.max(1))
            .map(|w| {
                let shared = Arc::clone(&shared);
                let batch_key = Arc::clone(&batch_key);
                let exec = Arc::clone(&exec);
                std::thread::Builder::new()
                    .name(format!("goomd-worker-{w}"))
                    .spawn(move || worker_loop(&shared, &*batch_key, &*exec))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, workers: Mutex::new(handles) }
    }

    /// Non-blocking submit; rejects when the queue is at capacity.
    pub fn try_submit(&self, job: J) -> Result<(), SubmitError<J>> {
        let mut st = self.shared.state.lock().expect("pool lock");
        if st.shutdown {
            return Err(SubmitError::Shutdown(job));
        }
        if st.queue.len() >= self.shared.depth {
            return Err(SubmitError::Full(job));
        }
        st.queue.push_back(job);
        drop(st);
        self.shared.available.notify_one();
        Ok(())
    }

    /// Jobs currently waiting (not counting in-flight execution).
    pub fn queue_len(&self) -> usize {
        self.shared.state.lock().expect("pool lock").queue.len()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.depth
    }

    /// Stop accepting work, wake every worker, and join them. Queued but
    /// unstarted jobs are dropped (their reply channels close, which the
    /// session layer reports as a shutdown error).
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().expect("pool lock");
            st.shutdown = true;
            st.queue.clear();
        }
        self.shared.available.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().expect("pool workers lock"));
        for h in handles {
            let _ = h.join();
        }
    }

    /// Graceful twin of [`shutdown`](Self::shutdown): stop accepting work
    /// but keep every queued job. Workers drain the queue to empty (the
    /// loop only observes the shutdown flag once the queue is dry), then
    /// exit; this joins them. Idempotent, and a later `shutdown()` (e.g.
    /// from `Drop`) finds nothing left to do.
    pub fn drain(&self) {
        {
            let mut st = self.shared.state.lock().expect("pool lock");
            st.shutdown = true;
        }
        self.shared.available.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().expect("pool workers lock"));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl<J: Send + 'static> Drop for Pool<J> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop<J, K, E>(shared: &Shared<J>, batch_key: &K, exec: &E)
where
    J: Send,
    K: Fn(&J) -> Option<String>,
    E: Fn(Vec<J>),
{
    loop {
        let batch = {
            let mut st = shared.state.lock().expect("pool lock");
            loop {
                if let Some(first) = st.queue.pop_front() {
                    let key = batch_key(&first);
                    let mut batch = vec![first];
                    if let Some(key) = key {
                        let mut i = 0;
                        while i < st.queue.len() && batch.len() < shared.batch_max {
                            if batch_key(&st.queue[i]).as_deref() == Some(key.as_str()) {
                                batch.push(st.queue.remove(i).expect("index in bounds"));
                            } else {
                                i += 1;
                            }
                        }
                    }
                    break batch;
                }
                if st.shutdown {
                    return;
                }
                st = shared.available.wait(st).expect("pool condvar");
            }
        };
        // Fault seam: a `stall_ms` plan delays execution (simulating a
        // slow kernel or a GC'd host) without touching the result — jobs
        // can only be late here, never wrong or lost.
        if super::faults::enabled() {
            if let super::faults::Fault::Stall(d) =
                super::faults::decide(super::faults::Site::PoolExec)
            {
                std::thread::sleep(d);
            }
        }
        exec(batch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    /// Test job: an id, an optional batch key, and a reply channel the
    /// executor reports (id, batch_size) through. `gate` (when set) makes
    /// the executor block until released, so tests control worker timing.
    struct TestJob {
        id: usize,
        key: Option<String>,
        gate: Option<mpsc::Receiver<()>>,
        started: Option<mpsc::Sender<()>>,
        reply: mpsc::Sender<(usize, usize)>,
    }

    fn pool_for_tests(workers: usize, depth: usize, batch_max: usize) -> Pool<TestJob> {
        Pool::new(
            workers,
            depth,
            batch_max,
            |j: &TestJob| j.key.clone(),
            |batch: Vec<TestJob>| {
                let size = batch.len();
                for j in batch {
                    if let Some(s) = &j.started {
                        s.send(()).unwrap();
                    }
                    if let Some(g) = &j.gate {
                        g.recv().unwrap();
                    }
                    j.reply.send((j.id, size)).unwrap();
                }
            },
        )
    }

    fn plain_job(id: usize, reply: &mpsc::Sender<(usize, usize)>) -> TestJob {
        TestJob { id, key: None, gate: None, started: None, reply: reply.clone() }
    }

    #[test]
    fn executes_every_submitted_job() {
        let pool = pool_for_tests(3, 64, 1);
        let (tx, rx) = mpsc::channel();
        for id in 0..40 {
            pool.try_submit(plain_job(id, &tx)).map_err(|_| "rejected").unwrap();
        }
        let mut seen: Vec<usize> =
            (0..40).map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap().0).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn rejects_when_queue_full_then_recovers() {
        let pool = pool_for_tests(1, 2, 1);
        let (tx, rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (started_tx, started_rx) = mpsc::channel();
        // Occupy the single worker with a gated job...
        pool.try_submit(TestJob {
            id: 0,
            key: None,
            gate: Some(gate_rx),
            started: Some(started_tx),
            reply: tx.clone(),
        })
        .map_err(|_| "rejected")
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        // ...fill the queue to depth...
        pool.try_submit(plain_job(1, &tx)).map_err(|_| "rejected").unwrap();
        pool.try_submit(plain_job(2, &tx)).map_err(|_| "rejected").unwrap();
        // ...and the next submit must shed load, handing the job back.
        match pool.try_submit(plain_job(3, &tx)) {
            Err(SubmitError::Full(j)) => assert_eq!(j.id, 3),
            Err(SubmitError::Shutdown(_)) => panic!("unexpected shutdown"),
            Ok(()) => panic!("expected Full rejection"),
        }
        assert_eq!(pool.queue_len(), 2);
        // Release the worker: queued jobs drain and capacity returns.
        gate_tx.send(()).unwrap();
        for _ in 0..3 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        pool.try_submit(plain_job(4, &tx)).map_err(|_| "rejected").unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap().0, 4);
        pool.shutdown();
    }

    #[test]
    fn drains_same_key_jobs_into_one_batch() {
        let pool = pool_for_tests(1, 64, 8);
        let (tx, rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (started_tx, started_rx) = mpsc::channel();
        // Block the worker so the queue builds up deterministically.
        pool.try_submit(TestJob {
            id: 0,
            key: None,
            gate: Some(gate_rx),
            started: Some(started_tx),
            reply: tx.clone(),
        })
        .map_err(|_| "rejected")
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let keyed = |id: usize, key: &str| TestJob {
            id,
            key: Some(key.to_string()),
            gate: None,
            started: None,
            reply: tx.clone(),
        };
        pool.try_submit(keyed(1, "k1")).map_err(|_| "rejected").unwrap();
        pool.try_submit(keyed(2, "k1")).map_err(|_| "rejected").unwrap();
        pool.try_submit(keyed(3, "k2")).map_err(|_| "rejected").unwrap();
        pool.try_submit(keyed(4, "k1")).map_err(|_| "rejected").unwrap();
        gate_tx.send(()).unwrap();
        let mut by_id = std::collections::BTreeMap::new();
        for _ in 0..5 {
            let (id, size) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            by_id.insert(id, size);
        }
        // The three k1 jobs ran as one batch; k2 ran alone; the blocker alone.
        assert_eq!(by_id[&0], 1);
        assert_eq!(by_id[&1], 3);
        assert_eq!(by_id[&2], 3);
        assert_eq!(by_id[&4], 3);
        assert_eq!(by_id[&3], 1);
        pool.shutdown();
    }

    #[test]
    fn batch_max_caps_batch_size() {
        let pool = pool_for_tests(1, 64, 2);
        let (tx, rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (started_tx, started_rx) = mpsc::channel();
        pool.try_submit(TestJob {
            id: 0,
            key: None,
            gate: Some(gate_rx),
            started: Some(started_tx),
            reply: tx.clone(),
        })
        .map_err(|_| "rejected")
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        for id in 1..=4 {
            pool.try_submit(TestJob {
                id,
                key: Some("k".into()),
                gate: None,
                started: None,
                reply: tx.clone(),
            })
            .map_err(|_| "rejected")
            .unwrap();
        }
        gate_tx.send(()).unwrap();
        for _ in 0..5 {
            let (_, size) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(size <= 2, "batch_max=2 violated: {size}");
        }
        pool.shutdown();
    }

    #[test]
    fn drain_finishes_queued_jobs_instead_of_dropping_them() {
        let pool = pool_for_tests(1, 64, 1);
        let (tx, rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (started_tx, started_rx) = mpsc::channel();
        // Pin the worker, pile up queued jobs behind it.
        pool.try_submit(TestJob {
            id: 0,
            key: None,
            gate: Some(gate_rx),
            started: Some(started_tx),
            reply: tx.clone(),
        })
        .map_err(|_| "rejected")
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        for id in 1..=3 {
            pool.try_submit(plain_job(id, &tx)).map_err(|_| "rejected").unwrap();
        }
        // Release the gate from a helper thread so drain() can join.
        let release = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            gate_tx.send(()).unwrap();
        });
        pool.drain();
        release.join().unwrap();
        // Every job ran — drain keeps the queue, unlike shutdown.
        let mut seen: Vec<usize> =
            (0..4).map(|_| rx.recv_timeout(Duration::from_secs(10)).unwrap().0).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // New work is refused, and a follow-up shutdown is a no-op.
        match pool.try_submit(plain_job(9, &tx)) {
            Err(SubmitError::Shutdown(_)) => {}
            _ => panic!("expected Shutdown rejection after drain"),
        }
        pool.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let pool = pool_for_tests(2, 8, 1);
        pool.shutdown();
        let (tx, _rx) = mpsc::channel();
        match pool.try_submit(plain_job(0, &tx)) {
            Err(SubmitError::Shutdown(_)) => {}
            Err(SubmitError::Full(_)) => panic!("expected Shutdown, got Full"),
            Ok(()) => panic!("expected Shutdown, got acceptance"),
        }
    }
}
