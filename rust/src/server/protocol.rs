//! `goomd` wire protocol: newline-delimited JSON and GBIN binary frames
//! over TCP, mixed freely on one connection.
//!
//! **JSON framing** (the original protocol; unchanged): every request is
//! one JSON object on one line; every response is one JSON object on one
//! line. Requests select an operation with `"op"`:
//!
//! ```text
//! {"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}
//! {"op":"scan","d":2,"logmag":[[0,null,null,0]],"sign":[[1,1,1,1]],"chunks":16}
//! {"op":"lle","system":"lorenz","steps":4000,"burn":1000,"chunks":64}
//! {"op":"info"}
//! {"op":"metrics"}
//! {"op":"trace","limit":256}
//! ```
//!
//! Responses are `{"ok":true,"cached":…,"result":{…}}` or
//! `{"ok":false,"error":"…"}` (with `"retry_after_ms"` when the server is
//! shedding load and the client should back off and retry).
//!
//! **Binary framing** (opt-in per message, negotiated by the first bytes):
//! a message starting with the GBIN-derived magic [`FRAME_MAGIC`]
//! (`"GBF1"`) is a length-prefixed frame — `magic | u32 payload_len |
//! payload` — whose dense tensor payloads ride the `runtime/gbin.rs`
//! container instead of float text. Anything else (JSON starts `{`) is a
//! newline-framed line, so existing clients keep working unmodified. A
//! binary request decodes to the same [`Request`] value as its JSON twin,
//! so both spellings share one canonical form, one cache key, and one
//! rendezvous placement by construction. Responses answer in the
//! encoding of their request. See `docs/SERVING.md` § Wire protocol for
//! the full layout and compatibility matrix.
//!
//! Any request may carry an optional `"id"` (string or integer): it is
//! echoed verbatim as the first key of the response line (or the id slot
//! of the response frame), forwarded router → shard so cross-tier traces
//! stitch on it, and — while tracing is sampled on (`--trace-sample`) —
//! it forces the request to be traced (see [`crate::obs`]). The `id` is
//! *not* part of the canonical form: cache identity and rendezvous
//! routing ignore it.
//!
//! GOOM zeros (logmag = -inf) have no JSON literal; the JSON protocol
//! encodes them as `null` in `logmag` arrays, both directions. Binary
//! frames carry them natively as IEEE `-inf`.
//!
//! Decoding validates *shape and bounds* here; semantic checks that need
//! the wider library (e.g. whether a dynamical system exists) happen at
//! execution so this module stays dependency-light and unit-testable.

use crate::chain::Method;
use crate::goom::GoomMat;
use crate::runtime::gbin::{self, HostTensor};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Hard per-request bounds: a single request must never be able to pin a
/// worker for unbounded time or memory.
///
/// `MAX_CHAIN_D` was 128 while the kernel packed full-depth panels (they
/// had to fit L2); the `KC` depth loop (`goom::kernel`) keeps panels
/// cache-resident at any dimension, so the cap is now a memory/time bound
/// only. Raising it must not raise the worst-case *time* one request can
/// pin a worker, so `d` and `steps` are additionally bound jointly by
/// [`MAX_CHAIN_WORK`] (one chain step costs ~2·d³ FLOPs). `MAX_SCAN_D`
/// stays payload-bound: scan operands travel in the request body as JSON,
/// so the line-size cap is the real limit there.
pub const MAX_CHAIN_D: usize = 1024;
/// Joint chain budget: `d³ · steps` may not exceed what the pre-KC caps
/// allowed at their combined worst case (128³ · 200 000) — e.g. `d = 1024`
/// is served up to ~390 steps, `d = 512` up to ~3 100.
pub const MAX_CHAIN_WORK: u128 = 128u128.pow(3) * 200_000;
pub const MAX_CHAIN_STEPS: usize = 200_000;
pub const MAX_SCAN_D: usize = 64;
pub const MAX_SCAN_LEN: usize = 4096;
pub const MAX_LLE_STEPS: usize = 200_000;
pub const MAX_LLE_BURN: usize = 1_000_000;
pub const MAX_CHUNKS: usize = 4096;
/// Bound on the `trace` op's span count (well past every ring's capacity).
pub const MAX_TRACE_LIMIT: usize = 100_000;

/// A decoded, bounds-checked request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Chain(ChainReq),
    Scan(ScanReq),
    Lle(LleReq),
    Info,
    Metrics,
    /// Recent trace spans (most recent `limit`), newest last.
    Trace { limit: usize },
}

/// Fig.-1 matrix-product chain over any served [`Method`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainReq {
    pub method: Method,
    pub d: usize,
    pub steps: usize,
    pub seed: u64,
}

/// Prefix scan (cumulative `S_t = A_t · S_{t-1}`) over client-supplied GOOM
/// transition matrices. The response carries the final state.
#[derive(Debug, Clone, PartialEq)]
pub struct ScanReq {
    pub d: usize,
    pub mats: Vec<GoomMat<f64>>,
    pub chunks: usize,
}

/// Largest-Lyapunov-exponent estimate for a registered `dynsys` system.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LleReq {
    pub system: String,
    pub steps: usize,
    pub burn: usize,
    pub chunks: usize,
}

/// Canonical lowercase slug for a method (stable across releases — part of
/// the wire protocol and the cache key).
pub fn method_slug(m: Method) -> &'static str {
    match m {
        Method::F32 => "f32",
        Method::F64 => "f64",
        Method::GoomC64 => "goomc64",
        Method::GoomC128 => "goomc128",
        Method::GoomHlo => "goomhlo",
    }
}

// ---------------------------------------------------------------- decode --

fn bounded_usize(
    doc: &Json,
    key: &str,
    default: usize,
    min: usize,
    max: usize,
) -> Result<usize, String> {
    let v = match doc.get(key) {
        None => return Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| format!("'{key}' must be a non-negative integer"))?,
    };
    if v < min || v > max {
        return Err(format!("'{key}' = {v} out of range [{min}, {max}]"));
    }
    Ok(v)
}

fn seed_field(doc: &Json, default: u64) -> Result<u64, String> {
    match doc.get("seed") {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or("'seed' must be a number")?;
            if x < 0.0 || x.fract() != 0.0 || x >= 9_007_199_254_740_992.0 {
                return Err("'seed' must be an integer in [0, 2^53)".to_string());
            }
            Ok(x as u64)
        }
    }
}

impl Request {
    /// Decode and bounds-check one request document.
    pub fn parse(doc: &Json) -> Result<Request, String> {
        let op = doc
            .get("op")
            .and_then(Json::as_str)
            .ok_or("missing string field 'op'")?;
        match op {
            "info" => Ok(Request::Info),
            "metrics" => Ok(Request::Metrics),
            "trace" => Ok(Request::Trace {
                limit: bounded_usize(
                    doc,
                    "limit",
                    crate::obs::DEFAULT_TRACE_LIMIT,
                    1,
                    MAX_TRACE_LIMIT,
                )?,
            }),
            "chain" => Self::parse_chain(doc),
            "scan" => Self::parse_scan(doc),
            "lle" => Self::parse_lle(doc),
            other => Err(format!(
                "unknown op '{other}' (expected chain|scan|lle|info|metrics|trace)"
            )),
        }
    }

    fn parse_chain(doc: &Json) -> Result<Request, String> {
        let method_str = doc
            .get("method")
            .map(|v| v.as_str().ok_or("'method' must be a string"))
            .transpose()?
            .unwrap_or("goomc64");
        let method = Method::parse(method_str)
            .ok_or_else(|| format!("unknown method '{method_str}'"))?;
        if method == Method::GoomHlo {
            return Err(
                "method 'goomhlo' needs the AOT/PJRT engine and is not served; \
                 use goomc64/goomc128"
                    .to_string(),
            );
        }
        let d = bounded_usize(doc, "d", 8, 1, MAX_CHAIN_D)?;
        let steps = bounded_usize(doc, "steps", 1000, 0, MAX_CHAIN_STEPS)?;
        let work = (d as u128).pow(3) * steps as u128;
        if work > MAX_CHAIN_WORK {
            return Err(format!(
                "chain work d^3*steps = {work} exceeds the budget {MAX_CHAIN_WORK}; \
                 reduce 'steps' at large 'd'"
            ));
        }
        Ok(Request::Chain(ChainReq { method, d, steps, seed: seed_field(doc, 42)? }))
    }

    fn parse_scan(doc: &Json) -> Result<Request, String> {
        let d = bounded_usize(doc, "d", 0, 1, MAX_SCAN_D)?;
        if d == 0 {
            return Err("scan requires 'd' (matrix dimension)".to_string());
        }
        let logmag = doc
            .get("logmag")
            .and_then(Json::as_arr)
            .ok_or("scan requires 'logmag': array of arrays")?;
        let sign = doc
            .get("sign")
            .and_then(Json::as_arr)
            .ok_or("scan requires 'sign': array of arrays")?;
        if logmag.is_empty() {
            return Err("'logmag' must hold at least one matrix".to_string());
        }
        if logmag.len() > MAX_SCAN_LEN {
            return Err(format!(
                "'logmag' holds {} matrices (max {MAX_SCAN_LEN})",
                logmag.len()
            ));
        }
        if sign.len() != logmag.len() {
            return Err(format!(
                "'sign' holds {} matrices but 'logmag' holds {}",
                sign.len(),
                logmag.len()
            ));
        }
        let mut mats = Vec::with_capacity(logmag.len());
        for (t, (lm, sg)) in logmag.iter().zip(sign.iter()).enumerate() {
            let lm = lm
                .as_arr()
                .ok_or_else(|| format!("logmag[{t}] is not an array"))?;
            let sg = sg
                .as_arr()
                .ok_or_else(|| format!("sign[{t}] is not an array"))?;
            if lm.len() != d * d || sg.len() != d * d {
                return Err(format!(
                    "matrix {t}: expected {} entries (d={d}), got logmag {} / sign {}",
                    d * d,
                    lm.len(),
                    sg.len()
                ));
            }
            let mut m = GoomMat::<f64>::zeros(d, d);
            for (i, (l, s)) in lm.iter().zip(sg.iter()).enumerate() {
                m.logmag[i] = match l {
                    Json::Null => f64::NEG_INFINITY, // GOOM zero
                    other => other
                        .as_f64()
                        .ok_or_else(|| format!("logmag[{t}][{i}] not a number"))?,
                };
                let s = s
                    .as_f64()
                    .ok_or_else(|| format!("sign[{t}][{i}] not a number"))?;
                if s != 1.0 && s != -1.0 {
                    return Err(format!("sign[{t}][{i}] must be 1 or -1, got {s}"));
                }
                m.sign[i] = s;
            }
            mats.push(m);
        }
        Ok(Request::Scan(ScanReq {
            d,
            mats,
            chunks: bounded_usize(doc, "chunks", 16, 1, MAX_CHUNKS)?,
        }))
    }

    fn parse_lle(doc: &Json) -> Result<Request, String> {
        let system = doc
            .get("system")
            .and_then(Json::as_str)
            .ok_or("lle requires string field 'system'")?
            .to_ascii_lowercase();
        Ok(Request::Lle(LleReq {
            system,
            steps: bounded_usize(doc, "steps", 4000, 1, MAX_LLE_STEPS)?,
            burn: bounded_usize(doc, "burn", 1000, 0, MAX_LLE_BURN)?,
            chunks: bounded_usize(doc, "chunks", 64, 1, MAX_CHUNKS)?,
        }))
    }

    /// Canonical wire form: the request re-encoded with every default made
    /// explicit, keys sorted (the JSON writer emits `BTreeMap` order).
    /// Always a parseable request line — the router forwards this instead
    /// of the client's spelling, so shards see normalized traffic. `None`
    /// for the introspection ops.
    pub fn canonical_line(&self) -> Option<String> {
        let doc = match self {
            Request::Info | Request::Metrics | Request::Trace { .. } => return None,
            Request::Chain(c) => obj(vec![
                ("op", Json::Str("chain".into())),
                ("method", Json::Str(method_slug(c.method).into())),
                ("d", num(c.d as f64)),
                ("steps", num(c.steps as f64)),
                ("seed", num(c.seed as f64)),
            ]),
            Request::Scan(s) => obj(vec![
                ("op", Json::Str("scan".into())),
                ("d", num(s.d as f64)),
                ("chunks", num(s.chunks as f64)),
                (
                    "logmag",
                    Json::Arr(
                        s.mats
                            .iter()
                            .map(|m| {
                                Json::Arr(
                                    m.logmag.iter().copied().map(num_or_null).collect(),
                                )
                            })
                            .collect(),
                    ),
                ),
                (
                    "sign",
                    Json::Arr(
                        s.mats
                            .iter()
                            .map(|m| {
                                Json::Arr(m.sign.iter().map(|&x| num(x)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            Request::Lle(l) => obj(vec![
                ("op", Json::Str("lle".into())),
                ("system", Json::Str(l.system.clone())),
                ("steps", num(l.steps as f64)),
                ("burn", num(l.burn as f64)),
                ("chunks", num(l.chunks as f64)),
            ]),
        };
        Some(json::write(&doc))
    }

    /// Canonical cache key: [`canonical_line`](Self::canonical_line), with
    /// large canonical forms (scan payloads run to `max_request_bytes`)
    /// digested to a fixed-size key so the entry-count LRU cannot be made
    /// to retain gigabytes of key strings. `None` for the introspection
    /// ops, which are never cached.
    pub fn canonical_key(&self) -> Option<String> {
        let full = self.canonical_line()?;
        Some(if full.len() > MAX_VERBATIM_KEY_BYTES {
            digest_key(&full)
        } else {
            full
        })
    }

    /// Pool batch key: requests sharing a key may be executed together in
    /// one stacked pass. GOOM chain requests batch by (method, d) — they
    /// share the per-step LMME — and scan requests batch by dimension,
    /// advancing their chunked folds in lockstep. Float chains and LLE
    /// run solo.
    pub fn batch_key(&self) -> Option<String> {
        match self {
            Request::Chain(c)
                if c.method == Method::GoomC64 || c.method == Method::GoomC128 =>
            {
                Some(format!("chain:{}:{}", method_slug(c.method), c.d))
            }
            Request::Scan(s) => Some(format!("scan:{}", s.d)),
            _ => None,
        }
    }

    /// Admission cost in the [`MAX_CHAIN_WORK`] currency (`d³ · steps` —
    /// each chain step is one d×d LMME at ~2·d³ FLOPs). Scans charge one
    /// d×d combine per supplied matrix; LLE runs on tiny (≈3-dim) tangent
    /// systems, so each step is charged at the smallest cube that bounds
    /// it. Introspection ops are free — they never reach the pool.
    pub fn work_units(&self) -> u128 {
        match self {
            Request::Chain(c) => (c.d as u128).pow(3) * c.steps as u128,
            Request::Scan(s) => (s.d as u128).pow(3) * s.mats.len() as u128,
            Request::Lle(l) => 27 * (l.steps + l.burn) as u128,
            Request::Info | Request::Metrics | Request::Trace { .. } => 0,
        }
    }
}

/// Canonical keys longer than this are replaced by a 128-bit digest
/// (2×64-bit SipHash with distinct prefixes, plus the original length).
/// Accidental collisions are negligible at cache scale; the daemon is not
/// hardened against adversarial collision construction.
const MAX_VERBATIM_KEY_BYTES: usize = 4096;

fn digest_key(full: &str) -> String {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h1 = DefaultHasher::new();
    0u8.hash(&mut h1);
    full.hash(&mut h1);
    let mut h2 = DefaultHasher::new();
    1u8.hash(&mut h2);
    full.hash(&mut h2);
    format!("digest:{}:{:016x}{:016x}", full.len(), h1.finish(), h2.finish())
}

// ---------------------------------------------------------------- encode --

/// Build a JSON object from pairs (convenience for response assembly).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Shorthand for a JSON number.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// JSON has no ±inf/NaN: encode non-finite magnitudes as `null` (the GOOM
/// zero convention on the wire).
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// A success response line (no trailing newline).
pub fn ok_line(result: Json, cached: bool) -> String {
    json::write(&obj(vec![
        ("ok", Json::Bool(true)),
        ("cached", Json::Bool(cached)),
        ("result", result),
    ]))
}

/// An error response line (no trailing newline). `retry_after_ms` marks
/// load-shedding rejections the client should retry after backing off.
pub fn err_line(msg: &str, retry_after_ms: Option<u64>) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ];
    if let Some(ms) = retry_after_ms {
        pairs.push(("retry_after_ms", num(ms as f64)));
    }
    json::write(&obj(pairs))
}

/// Cap on a client-supplied `id`'s serialized form: ids are echoed on
/// every response and copied into trace spans, so they must stay small.
pub const MAX_ID_BYTES: usize = 256;

/// Validate the optional request `id`: absent, a string, or an integer in
/// `[0, 2^53)` (the range the JSON writer reproduces exactly). Anything
/// else is a protocol error — silently dropping a malformed id would break
/// the client's response matching.
pub fn parse_id(doc: &Json) -> Result<Option<Json>, String> {
    match doc.get("id") {
        None => Ok(None),
        Some(v) => validate_id_value(v).map(Some),
    }
}

/// The `id` validity rule shared by both protocols: a string of bounded
/// size, or an integer in `[0, 2^53)` (the range the JSON writer
/// reproduces exactly).
pub fn validate_id_value(v: &Json) -> Result<Json, String> {
    match v {
        Json::Str(s) => {
            if s.len() > MAX_ID_BYTES {
                return Err(format!("'id' exceeds {MAX_ID_BYTES} bytes"));
            }
            Ok(Json::Str(s.clone()))
        }
        Json::Num(x) => {
            if *x < 0.0 || x.fract() != 0.0 || *x >= 9_007_199_254_740_992.0 {
                return Err("'id' must be a string or an integer in [0, 2^53)".to_string());
            }
            Ok(Json::Num(*x))
        }
        _ => Err("'id' must be a string or an integer".to_string()),
    }
}

/// Splice the echoed `id` onto a finished response line as its first key.
/// Response lines are single JSON objects, so prefix insertion keeps the
/// body byte-identical — crucially, a shard-computed line fanned to many
/// coalesced waiters gets each waiter's own id without re-serializing the
/// result. Non-object lines (impossible today) pass through unchanged.
pub fn attach_id(line: &str, id: &Json) -> String {
    let Some(rest) = line.strip_prefix('{') else {
        return line.to_string();
    };
    let id_txt = json::write(id);
    if rest.starts_with('}') {
        format!("{{\"id\":{id_txt}{rest}")
    } else {
        format!("{{\"id\":{id_txt},{rest}")
    }
}

/// Client-side encoder for a chain request (used by `repro loadgen` and the
/// round-trip tests).
pub fn encode_chain_request(method: &str, d: usize, steps: usize, seed: u64) -> String {
    json::write(&obj(vec![
        ("op", Json::Str("chain".into())),
        ("method", Json::Str(method.to_string())),
        ("d", num(d as f64)),
        ("steps", num(steps as f64)),
        ("seed", num(seed as f64)),
    ]))
}

/// Client-side encoder for a scan request over real-valued matrices
/// (log-mapped on the client; mirrors `GoomMat::from_mat`).
pub fn encode_scan_request(mats: &[GoomMat<f64>], chunks: usize) -> String {
    let d = mats.first().map_or(0, |m| m.rows);
    json::write(&obj(vec![
        ("op", Json::Str("scan".into())),
        ("d", num(d as f64)),
        ("chunks", num(chunks as f64)),
        (
            "logmag",
            Json::Arr(
                mats.iter()
                    .map(|m| {
                        Json::Arr(m.logmag.iter().copied().map(num_or_null).collect())
                    })
                    .collect(),
            ),
        ),
        (
            "sign",
            Json::Arr(
                mats.iter()
                    .map(|m| Json::Arr(m.sign.iter().map(|&x| num(x)).collect()))
                    .collect(),
            ),
        ),
    ]))
}

// ------------------------------------------------------- binary framing --

/// Binary frame magic, derived from the gbin container's `"GBIN"`: `GB` +
/// `F1` for "frame, version 1". JSON lines start with `{` (or whitespace),
/// so the first bytes of any message classify it unambiguously.
pub const FRAME_MAGIC: [u8; 4] = *b"GBF1";

/// Bytes of `magic | u32 payload_len` before the payload.
pub const FRAME_HEADER: usize = 8;

const REQ_TAG: u8 = 0x01;
const RESP_TAG: u8 = 0x02;

/// Result-body encodings inside an ok response frame.
const RESULT_JSON: u8 = 0;
const RESULT_SCAN: u8 = 1;

/// Which encoding a message arrived in — responses always answer in kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    Json,
    Binary,
}

/// One finished wire response in a concrete encoding. `Json` payloads are
/// complete response lines without the terminator (the flush path appends
/// `\n`); `Bin` payloads are complete frames written verbatim. Bytes are
/// reference-counted so cache hits and coalesced fan-outs share one
/// encoding instead of re-serializing (or even copying) it per waiter.
#[derive(Clone, Debug)]
pub enum Payload {
    Json(Arc<str>),
    Bin(Arc<[u8]>),
}

impl Payload {
    /// Append this response's exact wire bytes to an output buffer — the
    /// single buffered write a cache hit costs (no allocation: the bytes
    /// were encoded when the entry was filled).
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        match self {
            Payload::Json(s) => {
                out.extend_from_slice(s.as_bytes());
                out.push(b'\n');
            }
            Payload::Bin(b) => out.extend_from_slice(b),
        }
    }

    /// Bytes this response occupies on the wire (terminator included).
    pub fn wire_len(&self) -> usize {
        match self {
            Payload::Json(s) => s.len() + 1,
            Payload::Bin(b) => b.len(),
        }
    }
}

impl From<String> for Payload {
    fn from(s: String) -> Self {
        Payload::Json(Arc::from(s))
    }
}

impl From<Vec<u8>> for Payload {
    fn from(b: Vec<u8>) -> Self {
        Payload::Bin(Arc::from(b))
    }
}

/// A response rendered once in *both* encodings, id-less and canonical.
/// This is what the in-flight registry fans out and what the cache stores:
/// each waiter picks its own wire's bytes (an `Arc` clone) and splices its
/// own id, so N coalesced waiters — JSON and binary mixed — share two
/// serializations total, and a cache hit re-encodes nothing.
#[derive(Clone, Debug)]
pub struct Rendered {
    pub json: Arc<str>,
    pub bin: Arc<[u8]>,
}

/// How to encode a success result into a binary frame body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RespKind {
    /// Compact JSON text inside the frame (small scalar documents:
    /// chain/lle results, introspection).
    Generic,
    /// Dense gbin tensor container (scan results: `logmag`/`sign`
    /// matrices plus a `meta` tensor).
    Scan,
}

impl Rendered {
    pub fn ok(result: &Json, cached: bool, kind: RespKind) -> Self {
        Rendered {
            json: Arc::from(ok_line(result.clone(), cached)),
            bin: Arc::from(encode_ok_frame(result, cached, kind, None)),
        }
    }

    pub fn err(msg: &str, retry_after_ms: Option<u64>) -> Self {
        Rendered {
            json: Arc::from(err_line(msg, retry_after_ms)),
            bin: Arc::from(encode_err_frame(msg, retry_after_ms, None)),
        }
    }

    /// Pick the wire encoding for one waiter and splice its id. With no id
    /// (the common case) this is an `Arc` clone — zero bytes copied.
    pub fn to_payload(&self, wire: Wire, id: Option<&Json>) -> Payload {
        match (wire, id) {
            (Wire::Json, None) => Payload::Json(Arc::clone(&self.json)),
            (Wire::Json, Some(id)) => Payload::Json(Arc::from(attach_id(&self.json, id))),
            (Wire::Binary, None) => Payload::Bin(Arc::clone(&self.bin)),
            (Wire::Binary, Some(id)) => Payload::Bin(Arc::from(frame_with_id(&self.bin, id))),
        }
    }
}

/// What the front of a mixed-protocol receive buffer holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameScan {
    /// Not enough bytes to classify or complete a message.
    NeedMore,
    /// A newline-framed text line: content ends at `nl` (the `\n` index).
    Line { nl: usize },
    /// A complete binary frame of `total` bytes (header + payload).
    Frame { total: usize },
    /// A binary frame header announcing a `len`-byte payload that has not
    /// fully arrived — callers can enforce size caps before buffering.
    PartialFrame { len: usize },
}

/// Classify the front of a buffer: binary iff it starts with the full
/// [`FRAME_MAGIC`] (a proper prefix of the magic is still ambiguous —
/// `NeedMore`); anything else is line-framed.
pub fn scan_wire(buf: &[u8]) -> FrameScan {
    let m = buf.len().min(FRAME_MAGIC.len());
    if buf[..m] == FRAME_MAGIC[..m] {
        if buf.len() < FRAME_HEADER {
            return FrameScan::NeedMore;
        }
        let len = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes")) as usize;
        let total = FRAME_HEADER + len;
        if buf.len() >= total {
            FrameScan::Frame { total }
        } else {
            FrameScan::PartialFrame { len }
        }
    } else {
        match buf.iter().position(|&b| b == b'\n') {
            Some(nl) => FrameScan::Line { nl },
            None => FrameScan::NeedMore,
        }
    }
}

/// Prepend the frame header to a finished payload.
fn wrap_frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Bounded little-endian reader over a frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!("binary frame truncated at byte {}", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn done(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{} trailing bytes after the frame body",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, x: usize) {
    out.extend_from_slice(&(x as u32).to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_id(out: &mut Vec<u8>, id: Option<&Json>) {
    match id {
        None => put_u32(out, 0),
        Some(id) => {
            let txt = json::write(id);
            put_u32(out, txt.len());
            out.extend_from_slice(txt.as_bytes());
        }
    }
}

fn take_id(cur: &mut Cur) -> Result<Option<Json>, String> {
    let n = cur.u32()? as usize;
    if n == 0 {
        return Ok(None);
    }
    if n > MAX_ID_BYTES {
        return Err(format!("'id' exceeds {MAX_ID_BYTES} bytes"));
    }
    let raw = cur.take(n)?;
    let txt = std::str::from_utf8(raw).map_err(|_| "'id' is not utf-8".to_string())?;
    let v = json::parse(txt).map_err(|e| format!("bad 'id': {e}"))?;
    validate_id_value(&v).map(Some)
}

const OP_CHAIN: u8 = 1;
const OP_SCAN: u8 = 2;
const OP_LLE: u8 = 3;
const OP_INFO: u8 = 4;
const OP_METRICS: u8 = 5;
const OP_TRACE: u8 = 6;

fn method_tag(m: Method) -> u8 {
    match m {
        Method::F32 => 0,
        Method::F64 => 1,
        Method::GoomC64 => 2,
        Method::GoomC128 => 3,
        Method::GoomHlo => unreachable!("goomhlo is rejected before encoding"),
    }
}

/// Encode one request as a complete binary frame. The encoding has no
/// defaults — every field is explicit and fixed-width — so a decoded
/// request re-encodes to the same bytes (the binary canonical form the
/// router forwards shard-ward).
pub fn encode_request_frame(req: &Request, id: Option<&Json>) -> Vec<u8> {
    let mut p = Vec::new();
    p.push(REQ_TAG);
    put_id(&mut p, id);
    match req {
        Request::Info => p.push(OP_INFO),
        Request::Metrics => p.push(OP_METRICS),
        Request::Trace { limit } => {
            p.push(OP_TRACE);
            put_u64(&mut p, *limit as u64);
        }
        Request::Chain(c) => {
            p.push(OP_CHAIN);
            p.push(method_tag(c.method));
            put_u32(&mut p, c.d);
            put_u64(&mut p, c.steps as u64);
            put_u64(&mut p, c.seed);
        }
        Request::Lle(l) => {
            p.push(OP_LLE);
            put_u32(&mut p, l.system.len());
            p.extend_from_slice(l.system.as_bytes());
            put_u64(&mut p, l.steps as u64);
            put_u64(&mut p, l.burn as u64);
            put_u32(&mut p, l.chunks);
        }
        Request::Scan(s) => {
            p.push(OP_SCAN);
            put_u32(&mut p, s.d);
            put_u32(&mut p, s.chunks);
            let n = s.mats.len();
            let mut logmag = Vec::with_capacity(n * s.d * s.d);
            let mut sign = Vec::with_capacity(n * s.d * s.d);
            for m in &s.mats {
                logmag.extend_from_slice(&m.logmag);
                sign.extend_from_slice(&m.sign);
            }
            let shape = vec![n, s.d, s.d];
            let mut tensors = BTreeMap::new();
            tensors.insert(
                "logmag".to_string(),
                HostTensor::F64 { shape: shape.clone(), data: logmag },
            );
            tensors.insert("sign".to_string(), HostTensor::F64 { shape, data: sign });
            p.extend_from_slice(&gbin::encode_gbin(&tensors));
        }
    }
    wrap_frame(p)
}

fn bounded(name: &str, v: u64, min: usize, max: usize) -> Result<usize, String> {
    if v < min as u64 || v > max as u64 {
        return Err(format!("'{name}' = {v} out of range [{min}, {max}]"));
    }
    Ok(v as usize)
}

/// Decode one binary request frame *payload* (header already stripped) to
/// the same `(Request, id)` its JSON twin parses to — every bounds check
/// mirrors [`Request::parse`] exactly, so both spellings share one
/// canonical form and one cache key by construction.
pub fn decode_request_frame(payload: &[u8]) -> Result<(Request, Option<Json>), String> {
    let mut cur = Cur { buf: payload, pos: 0 };
    if cur.u8()? != REQ_TAG {
        return Err("frame is not a request".to_string());
    }
    let id = take_id(&mut cur)?;
    let op = cur.u8()?;
    let req = match op {
        OP_INFO => {
            cur.done()?;
            Request::Info
        }
        OP_METRICS => {
            cur.done()?;
            Request::Metrics
        }
        OP_TRACE => {
            let limit = bounded("limit", cur.u64()?, 1, MAX_TRACE_LIMIT)?;
            cur.done()?;
            Request::Trace { limit }
        }
        OP_CHAIN => {
            let method = match cur.u8()? {
                0 => Method::F32,
                1 => Method::F64,
                2 => Method::GoomC64,
                3 => Method::GoomC128,
                other => return Err(format!("unknown method tag {other}")),
            };
            let d = bounded("d", cur.u32()? as u64, 1, MAX_CHAIN_D)?;
            let steps = bounded("steps", cur.u64()?, 0, MAX_CHAIN_STEPS)?;
            let seed = cur.u64()?;
            if seed >= 9_007_199_254_740_992 {
                return Err("'seed' must be an integer in [0, 2^53)".to_string());
            }
            cur.done()?;
            let work = (d as u128).pow(3) * steps as u128;
            if work > MAX_CHAIN_WORK {
                return Err(format!(
                    "chain work d^3*steps = {work} exceeds the budget {MAX_CHAIN_WORK}; \
                     reduce 'steps' at large 'd'"
                ));
            }
            Request::Chain(ChainReq { method, d, steps, seed })
        }
        OP_LLE => {
            let n = cur.u32()? as usize;
            let system = std::str::from_utf8(cur.take(n)?)
                .map_err(|_| "'system' is not utf-8".to_string())?
                .to_ascii_lowercase();
            let steps = bounded("steps", cur.u64()?, 1, MAX_LLE_STEPS)?;
            let burn = bounded("burn", cur.u64()?, 0, MAX_LLE_BURN)?;
            let chunks = bounded("chunks", cur.u32()? as u64, 1, MAX_CHUNKS)?;
            cur.done()?;
            Request::Lle(LleReq { system, steps, burn, chunks })
        }
        OP_SCAN => {
            let d = bounded("d", cur.u32()? as u64, 1, MAX_SCAN_D)?;
            let chunks = bounded("chunks", cur.u32()? as u64, 1, MAX_CHUNKS)?;
            let tensors =
                gbin::decode_gbin(cur.rest()).map_err(|e| format!("scan payload: {e:#}"))?;
            let (lm_shape, lm) = match tensors.get("logmag") {
                Some(HostTensor::F64 { shape, data }) => (shape, data),
                _ => return Err("scan requires an f64 'logmag' tensor".to_string()),
            };
            let (sg_shape, sg) = match tensors.get("sign") {
                Some(HostTensor::F64 { shape, data }) => (shape, data),
                _ => return Err("scan requires an f64 'sign' tensor".to_string()),
            };
            let n = match lm_shape.as_slice() {
                [n, rd, cd] if *rd == d && *cd == d => *n,
                other => {
                    return Err(format!("'logmag' shape {other:?} does not match [n, {d}, {d}]"))
                }
            };
            if sg_shape != lm_shape {
                return Err(format!(
                    "'sign' shape {sg_shape:?} does not match 'logmag' {lm_shape:?}"
                ));
            }
            if n == 0 {
                return Err("'logmag' must hold at least one matrix".to_string());
            }
            if n > MAX_SCAN_LEN {
                return Err(format!("'logmag' holds {n} matrices (max {MAX_SCAN_LEN})"));
            }
            let mut mats = Vec::with_capacity(n);
            for t in 0..n {
                let mut m = GoomMat::<f64>::zeros(d, d);
                let base = t * d * d;
                for i in 0..d * d {
                    let l = lm[base + i];
                    // JSON can only express finite magnitudes or the GOOM
                    // zero (`null` → -inf); hold binary to the same set so
                    // the canonical JSON form round-trips exactly.
                    if !l.is_finite() && l != f64::NEG_INFINITY {
                        return Err(format!("logmag[{t}][{i}] not a number"));
                    }
                    let s = sg[base + i];
                    if s != 1.0 && s != -1.0 {
                        return Err(format!("sign[{t}][{i}] must be 1 or -1, got {s}"));
                    }
                    m.logmag[i] = l;
                    m.sign[i] = s;
                }
                mats.push(m);
            }
            Request::Scan(ScanReq { d, mats, chunks })
        }
        other => return Err(format!("unknown op tag {other}")),
    };
    Ok((req, id))
}

/// Encode a success response frame. `RespKind::Scan` results travel as a
/// gbin tensor container (dense `logmag`/`sign` + a 3-entry `meta` tensor
/// `[d, len, log_frobenius]`); everything else embeds compact JSON text.
/// Non-finite scan values decode back to `null`, matching the JSON wire.
pub fn encode_ok_frame(result: &Json, cached: bool, kind: RespKind, id: Option<&Json>) -> Vec<u8> {
    let mut p = Vec::new();
    p.push(RESP_TAG);
    put_id(&mut p, id);
    p.push(1); // ok
    p.push(cached as u8);
    if kind == RespKind::Scan {
        if let Some(body) = scan_result_tensors(result) {
            p.push(RESULT_SCAN);
            p.extend_from_slice(&body);
            return wrap_frame(p);
        }
    }
    p.push(RESULT_JSON);
    let txt = json::write(result);
    put_u32(&mut p, txt.len());
    p.extend_from_slice(txt.as_bytes());
    wrap_frame(p)
}

/// Encode an error response frame (mirror of [`err_line`]).
pub fn encode_err_frame(msg: &str, retry_after_ms: Option<u64>, id: Option<&Json>) -> Vec<u8> {
    let mut p = Vec::new();
    p.push(RESP_TAG);
    put_id(&mut p, id);
    p.push(0); // err
    put_u32(&mut p, msg.len());
    p.extend_from_slice(msg.as_bytes());
    match retry_after_ms {
        None => p.push(0),
        Some(ms) => {
            p.push(1);
            put_u64(&mut p, ms);
        }
    }
    wrap_frame(p)
}

/// Build the gbin container for a scan result document; `None` when the
/// document does not look like one (the caller falls back to JSON text).
fn scan_result_tensors(result: &Json) -> Option<Vec<u8>> {
    let d = result.get("d")?.as_usize()?;
    let len = result.get("len")?.as_usize()?;
    let logmag = result.get("logmag")?.as_arr()?;
    let sign = result.get("sign")?.as_arr()?;
    let frob = result.get("log_frobenius")?;
    if logmag.len() != d * d || sign.len() != d * d {
        return None;
    }
    let to_f64 = |v: &Json| match v {
        Json::Null => Some(f64::NAN),
        Json::Num(x) => Some(*x),
        _ => None,
    };
    let lm: Option<Vec<f64>> = logmag.iter().map(to_f64).collect();
    let sg: Option<Vec<f64>> = sign.iter().map(to_f64).collect();
    let meta = vec![d as f64, len as f64, to_f64(frob)?];
    let mut tensors = BTreeMap::new();
    tensors.insert("logmag".to_string(), HostTensor::F64 { shape: vec![d, d], data: lm? });
    tensors.insert("sign".to_string(), HostTensor::F64 { shape: vec![d, d], data: sg? });
    tensors.insert("meta".to_string(), HostTensor::F64 { shape: vec![3], data: meta });
    Some(gbin::encode_gbin(&tensors))
}

/// Decode one binary response frame *payload* to the same JSON document
/// its newline twin parses to: `{"id":…,"ok":…,"cached":…,"result":…}` or
/// `{"id":…,"ok":false,"error":…,"retry_after_ms":…}`. Clients get one
/// document shape regardless of wire encoding — decoded results are
/// value-identical across protocols.
pub fn decode_response_frame(payload: &[u8]) -> Result<Json, String> {
    let mut cur = Cur { buf: payload, pos: 0 };
    if cur.u8()? != RESP_TAG {
        return Err("frame is not a response".to_string());
    }
    let id = take_id(&mut cur)?;
    let mut doc = BTreeMap::new();
    if let Some(id) = id {
        doc.insert("id".to_string(), id);
    }
    match cur.u8()? {
        0 => {
            let n = cur.u32()? as usize;
            let msg = std::str::from_utf8(cur.take(n)?)
                .map_err(|_| "error message is not utf-8".to_string())?
                .to_string();
            doc.insert("ok".to_string(), Json::Bool(false));
            doc.insert("error".to_string(), Json::Str(msg));
            if cur.u8()? != 0 {
                doc.insert("retry_after_ms".to_string(), num(cur.u64()? as f64));
            }
            cur.done()?;
        }
        1 => {
            let cached = cur.u8()? != 0;
            doc.insert("ok".to_string(), Json::Bool(true));
            doc.insert("cached".to_string(), Json::Bool(cached));
            let result = match cur.u8()? {
                RESULT_JSON => {
                    let n = cur.u32()? as usize;
                    let txt = std::str::from_utf8(cur.take(n)?)
                        .map_err(|_| "result is not utf-8".to_string())?;
                    cur.done()?;
                    json::parse(txt).map_err(|e| format!("bad result json: {e}"))?
                }
                RESULT_SCAN => {
                    let tensors = gbin::decode_gbin(cur.rest())
                        .map_err(|e| format!("scan result payload: {e:#}"))?;
                    decode_scan_result(&tensors)?
                }
                other => return Err(format!("unknown result kind {other}")),
            };
            doc.insert("result".to_string(), result);
        }
        other => return Err(format!("unknown response status {other}")),
    }
    Ok(Json::Obj(doc))
}

fn decode_scan_result(tensors: &BTreeMap<String, HostTensor>) -> Result<Json, String> {
    let meta = match tensors.get("meta") {
        Some(HostTensor::F64 { data, .. }) if data.len() == 3 => data,
        _ => return Err("scan result missing 3-entry 'meta' tensor".to_string()),
    };
    let lm = match tensors.get("logmag") {
        Some(HostTensor::F64 { data, .. }) => data,
        _ => return Err("scan result missing f64 'logmag' tensor".to_string()),
    };
    let sg = match tensors.get("sign") {
        Some(HostTensor::F64 { data, .. }) => data,
        _ => return Err("scan result missing f64 'sign' tensor".to_string()),
    };
    // Exactly `scan_result_json`'s document: non-finite magnitudes (GOOM
    // zeros, overflow) become `null`, signs stay plain numbers.
    Ok(obj(vec![
        ("d", num(meta[0])),
        ("len", num(meta[1])),
        ("logmag", Json::Arr(lm.iter().copied().map(num_or_null).collect())),
        ("sign", Json::Arr(sg.iter().map(|&x| num(x)).collect())),
        ("log_frobenius", num_or_null(meta[2])),
    ]))
}

/// Splice an `id` into a finished id-less response frame — the binary
/// analogue of [`attach_id`]: the frame body past the id slot is reused
/// byte-for-byte, so coalesced waiters sharing one rendered frame each
/// get their own id without re-encoding the result.
pub fn frame_with_id(frame: &[u8], id: &Json) -> Vec<u8> {
    // magic(4) | len(4) | tag(1) | id_len(4) | id | rest
    if frame.len() < FRAME_HEADER + 5 || frame[..4] != FRAME_MAGIC {
        return frame.to_vec();
    }
    let old_id_len = u32::from_le_bytes(frame[9..13].try_into().expect("4 bytes")) as usize;
    let rest_at = FRAME_HEADER + 5 + old_id_len;
    if rest_at > frame.len() {
        return frame.to_vec();
    }
    let id_txt = json::write(id);
    let rest = &frame[rest_at..];
    let payload_len = 5 + id_txt.len() + rest.len();
    let mut out = Vec::with_capacity(FRAME_HEADER + payload_len);
    out.extend_from_slice(&FRAME_MAGIC);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.push(frame[FRAME_HEADER]);
    out.extend_from_slice(&(id_txt.len() as u32).to_le_bytes());
    out.extend_from_slice(id_txt.as_bytes());
    out.extend_from_slice(rest);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    fn parse_line(line: &str) -> Result<Request, String> {
        let doc = json::parse(line).map_err(|e| e.to_string())?;
        Request::parse(&doc)
    }

    #[test]
    fn chain_request_round_trips_through_encode_and_parse() {
        let line = encode_chain_request("goomc128", 16, 5000, 7);
        let req = parse_line(&line).unwrap();
        assert_eq!(
            req,
            Request::Chain(ChainReq {
                method: Method::GoomC128,
                d: 16,
                steps: 5000,
                seed: 7
            })
        );
        // Canonical key is itself parseable and stable.
        let key = req.canonical_key().unwrap();
        let req2 = parse_line(&key).unwrap();
        assert_eq!(req, req2);
        assert_eq!(key, req2.canonical_key().unwrap());
    }

    #[test]
    fn chain_defaults_are_canonicalized_into_the_key() {
        // A request relying on defaults and one spelling them out must map
        // to the same cache key.
        let implicit = parse_line(r#"{"op":"chain"}"#).unwrap();
        let explicit =
            parse_line(r#"{"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}"#)
                .unwrap();
        assert_eq!(implicit.canonical_key(), explicit.canonical_key());
    }

    #[test]
    fn scan_request_round_trips_with_goom_zeros() {
        let mut rng = rng_from_seed(90);
        let mut mats: Vec<GoomMat<f64>> =
            (0..3).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        mats[1].logmag[2] = f64::NEG_INFINITY; // a GOOM zero → null on the wire
        let line = encode_scan_request(&mats, 4);
        let Request::Scan(s) = parse_line(&line).unwrap() else {
            panic!("not a scan")
        };
        assert_eq!(s.d, 2);
        assert_eq!(s.chunks, 4);
        assert_eq!(s.mats, mats);
    }

    #[test]
    fn rejects_malformed_and_out_of_bounds() {
        assert!(parse_line("42").is_err());
        assert!(parse_line(r#"{"no_op":1}"#).is_err());
        assert!(parse_line(r#"{"op":"fry"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","method":"quantum"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","method":"hlo"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","d":0}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","d":10000}"#).is_err());
        // The KC kernel lifted the old d ≤ 128 serving cap: dimensions up
        // to MAX_CHAIN_D now decode, but d and steps are jointly bounded
        // by the work budget so one request still cannot pin a worker for
        // longer than the pre-KC worst case.
        assert!(parse_line(r#"{"op":"chain","d":512}"#).is_ok());
        assert!(parse_line(
            &format!(r#"{{"op":"chain","d":{MAX_CHAIN_D},"steps":200}}"#)
        )
        .is_ok());
        assert!(parse_line(
            &format!(r#"{{"op":"chain","d":{},"steps":200}}"#, MAX_CHAIN_D + 1)
        )
        .is_err());
        assert!(
            parse_line(r#"{"op":"chain","d":1024,"steps":5000}"#).is_err(),
            "over the d^3*steps budget"
        );
        // At d = 128 the full historical step range still decodes.
        assert!(parse_line(r#"{"op":"chain","d":128,"steps":200000}"#).is_ok());
        assert!(parse_line(r#"{"op":"chain","steps":99999999}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","seed":-1}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","seed":1.5}"#).is_err());
        assert!(parse_line(r#"{"op":"lle","steps":10}"#).is_err()); // no system
        assert!(parse_line(r#"{"op":"scan","d":2}"#).is_err()); // no payload
        assert!(
            parse_line(r#"{"op":"scan","d":2,"logmag":[[0,0,0,0]],"sign":[[1,2,1,1]]}"#)
                .is_err(),
            "non-±1 sign must be rejected"
        );
        assert!(
            parse_line(r#"{"op":"scan","d":2,"logmag":[[0,0,0]],"sign":[[1,1,1]]}"#)
                .is_err(),
            "wrong entry count must be rejected"
        );
    }

    #[test]
    fn large_scan_payloads_get_fixed_size_digest_keys() {
        let mut rng = rng_from_seed(91);
        // 32 8x8 matrices serialize far past the 4 KiB verbatim-key cap.
        let mats: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let line = encode_scan_request(&mats, 8);
        let req = parse_line(&line).unwrap();
        let key = req.canonical_key().unwrap();
        assert!(key.starts_with("digest:"), "expected digest key, got {} bytes", key.len());
        assert!(key.len() < 128, "digest keys must stay small: {}", key.len());
        // Deterministic for identical payloads, distinct for different ones.
        assert_eq!(key, parse_line(&line).unwrap().canonical_key().unwrap());
        let other: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let other_key =
            parse_line(&encode_scan_request(&other, 8)).unwrap().canonical_key().unwrap();
        assert_ne!(key, other_key);
        // Small requests keep their verbatim (parseable) canonical form.
        let small = parse_line(r#"{"op":"chain"}"#).unwrap();
        assert!(!small.canonical_key().unwrap().starts_with("digest:"));
    }

    #[test]
    fn batch_keys_group_same_shape_goom_chains_and_scans() {
        let a = parse_line(r#"{"op":"chain","method":"goomc64","d":8}"#).unwrap();
        let b = parse_line(r#"{"op":"chain","method":"goomc64","d":8,"seed":9}"#).unwrap();
        let c = parse_line(r#"{"op":"chain","method":"goomc64","d":16}"#).unwrap();
        let d = parse_line(r#"{"op":"chain","method":"f64","d":8}"#).unwrap();
        let e = parse_line(r#"{"op":"lle","system":"lorenz"}"#).unwrap();
        assert_eq!(a.batch_key(), b.batch_key());
        assert!(a.batch_key().is_some());
        assert_ne!(a.batch_key(), c.batch_key());
        assert_eq!(d.batch_key(), None);
        assert_eq!(e.batch_key(), None);
        // Same-dimension scans share a batch key regardless of payload;
        // other dimensions do not.
        let mut rng = rng_from_seed(5);
        let m2: Vec<GoomMat<f64>> =
            (0..2).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let n2: Vec<GoomMat<f64>> =
            (0..4).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let m3: Vec<GoomMat<f64>> =
            (0..2).map(|_| GoomMat::randn(3, 3, &mut rng)).collect();
        let s2 = parse_line(&encode_scan_request(&m2, 4)).unwrap();
        let t2 = parse_line(&encode_scan_request(&n2, 8)).unwrap();
        let s3 = parse_line(&encode_scan_request(&m3, 4)).unwrap();
        assert_eq!(s2.batch_key(), t2.batch_key());
        assert!(s2.batch_key().is_some());
        assert_ne!(s2.batch_key(), s3.batch_key());
        assert_ne!(s2.batch_key(), a.batch_key());
    }

    #[test]
    fn work_units_charge_in_the_chain_budget_currency() {
        let big = parse_line(r#"{"op":"chain","d":128,"steps":200000}"#).unwrap();
        assert_eq!(big.work_units(), MAX_CHAIN_WORK, "ceiling chain = full budget");
        let small = parse_line(r#"{"op":"chain","d":8,"steps":1000}"#).unwrap();
        assert_eq!(small.work_units(), 512 * 1000);
        assert!(big.work_units() > 100_000 * small.work_units() / 128);
        let mut rng = rng_from_seed(3);
        let mats: Vec<GoomMat<f64>> =
            (0..3).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let scan = parse_line(&encode_scan_request(&mats, 4)).unwrap();
        assert_eq!(scan.work_units(), 8 * 3);
        let lle = parse_line(r#"{"op":"lle","system":"lorenz","steps":100,"burn":50}"#)
            .unwrap();
        assert_eq!(lle.work_units(), 27 * 150);
        assert_eq!(Request::Info.work_units(), 0);
        assert_eq!(Request::Metrics.work_units(), 0);
    }

    #[test]
    fn canonical_line_is_always_a_parseable_normalized_request() {
        // Even when the cache key degrades to a digest (large scans), the
        // canonical line the router forwards stays a full request.
        let mut rng = rng_from_seed(92);
        let mats: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let req = parse_line(&encode_scan_request(&mats, 8)).unwrap();
        assert!(req.canonical_key().unwrap().starts_with("digest:"));
        let line = req.canonical_line().unwrap();
        assert_eq!(parse_line(&line).unwrap(), req, "line must round-trip");
        // Defaults are spelled out, so distinct spellings converge.
        let implicit = parse_line(r#"{"op":"chain"}"#).unwrap();
        let explicit = parse_line(
            r#"{"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}"#,
        )
        .unwrap();
        assert_eq!(implicit.canonical_line(), explicit.canonical_line());
        assert_eq!(Request::Info.canonical_line(), None);
    }

    #[test]
    fn response_lines_are_valid_json() {
        let ok = ok_line(obj(vec![("x", num(1.0))]), true);
        let parsed = json::parse(&ok).unwrap();
        assert_eq!(parsed.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("cached").unwrap().as_bool(), Some(true));
        let err = err_line("queue full", Some(250));
        let parsed = json::parse(&err).unwrap();
        assert_eq!(parsed.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(parsed.get("retry_after_ms").unwrap().as_usize(), Some(250));
        // Non-finite numbers must never leak into the wire format.
        assert_eq!(num_or_null(f64::NEG_INFINITY), Json::Null);
        assert_eq!(num_or_null(f64::NAN), Json::Null);
    }

    #[test]
    fn info_and_metrics_parse_and_are_uncached() {
        assert_eq!(parse_line(r#"{"op":"info"}"#).unwrap(), Request::Info);
        assert_eq!(parse_line(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(Request::Info.canonical_key(), None);
        assert_eq!(Request::Metrics.canonical_key(), None);
        assert_eq!(Request::Info.batch_key(), None);
    }

    #[test]
    fn trace_op_parses_with_bounded_limit_and_is_uncached() {
        assert_eq!(
            parse_line(r#"{"op":"trace"}"#).unwrap(),
            Request::Trace { limit: crate::obs::DEFAULT_TRACE_LIMIT }
        );
        assert_eq!(
            parse_line(r#"{"op":"trace","limit":32}"#).unwrap(),
            Request::Trace { limit: 32 }
        );
        assert!(parse_line(r#"{"op":"trace","limit":0}"#).is_err());
        assert!(parse_line(r#"{"op":"trace","limit":99999999}"#).is_err());
        let t = Request::Trace { limit: 8 };
        assert_eq!(t.canonical_key(), None, "trace answers are never cached");
        assert_eq!(t.canonical_line(), None);
        assert_eq!(t.batch_key(), None);
    }

    #[test]
    fn id_field_validates_and_canonical_forms_ignore_it() {
        let doc = json::parse(r#"{"op":"chain","id":"req-9"}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), Some(Json::Str("req-9".into())));
        let doc = json::parse(r#"{"op":"chain","id":42}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), Some(Json::Num(42.0)));
        let doc = json::parse(r#"{"op":"chain"}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), None);
        for bad in [
            r#"{"id":true}"#,
            r#"{"id":[1]}"#,
            r#"{"id":1.5}"#,
            r#"{"id":-3}"#,
        ] {
            let doc = json::parse(bad).unwrap();
            assert!(parse_id(&doc).is_err(), "{bad} must be rejected");
        }
        // The id never reaches cache identity or routing: the canonical
        // forms of an id'd request and its id-less twin are identical.
        let with = parse_line(r#"{"op":"chain","d":8,"id":"x"}"#).unwrap();
        let without = parse_line(r#"{"op":"chain","d":8}"#).unwrap();
        assert_eq!(with.canonical_line(), without.canonical_line());
        assert_eq!(with.canonical_key(), without.canonical_key());
    }

    #[test]
    fn attach_id_prefixes_without_touching_the_body() {
        let body = ok_line(obj(vec![("x", num(1.0))]), false);
        let tagged = attach_id(&body, &Json::Str("req-1".into()));
        assert!(tagged.starts_with(r#"{"id":"req-1","#), "got {tagged}");
        assert_eq!(&tagged[r#"{"id":"req-1","#.len()..], &body[1..]);
        let doc = json::parse(&tagged).unwrap();
        assert_eq!(doc.get("id").unwrap().as_str(), Some("req-1"));
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
        // Numeric ids and the empty-object edge stay valid JSON too.
        let n = attach_id("{}", &Json::Num(7.0));
        assert_eq!(json::parse(&n).unwrap().get("id").unwrap().as_usize(), Some(7));
        let err = attach_id(&err_line("nope", None), &Json::Num(3.0));
        let doc = json::parse(&err).unwrap();
        assert_eq!(doc.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false));
    }

    // ------------------------------------------------- binary frame codec --

    fn decode_frame(frame: &[u8]) -> Result<(Request, Option<Json>), String> {
        assert_eq!(&frame[..4], &FRAME_MAGIC);
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(frame.len(), FRAME_HEADER + len, "self-describing length");
        decode_request_frame(&frame[FRAME_HEADER..])
    }

    fn random_scan_req(seed: u64, d: usize, n: usize) -> Request {
        let mut rng = rng_from_seed(seed);
        let mut mats = Vec::with_capacity(n);
        for _ in 0..n {
            let mut m = GoomMat::<f64>::zeros(d, d);
            for i in 0..d * d {
                m.logmag[i] = match rng.next_u64() % 8 {
                    0 => f64::NEG_INFINITY, // GOOM zero
                    _ => (rng.next_u64() % 2_000_000) as f64 / 1000.0 - 1000.0,
                };
                m.sign[i] = if rng.next_u64() % 2 == 0 { 1.0 } else { -1.0 };
            }
            mats.push(m);
        }
        Request::Scan(ScanReq { d, mats, chunks: 1 + (seed as usize % MAX_CHUNKS) })
    }

    #[test]
    fn binary_request_frames_round_trip_every_op() {
        let reqs = vec![
            Request::Info,
            Request::Metrics,
            Request::Trace { limit: 77 },
            Request::Chain(ChainReq {
                method: Method::GoomC128,
                d: 16,
                steps: 5000,
                seed: 9_007_199_254_740_991, // 2^53 - 1, the largest JSON-exact seed
            }),
            Request::Lle(LleReq {
                system: "lorenz".into(),
                steps: 4000,
                burn: 1000,
                chunks: 64,
            }),
            random_scan_req(11, 3, 5),
        ];
        for req in reqs {
            for id in [None, Some(Json::Str("req-1".into())), Some(Json::Num(7.0))] {
                let frame = encode_request_frame(&req, id.as_ref());
                let (back, back_id) = decode_frame(&frame).unwrap();
                assert_eq!(back, req);
                assert_eq!(back_id, id);
                // Binary canonical form: decode∘encode is the identity on
                // frames, like canonical_line round-trips for JSON.
                assert_eq!(encode_request_frame(&back, back_id.as_ref()), frame);
            }
        }
    }

    #[test]
    fn binary_and_json_twins_share_one_canonical_key() {
        let chain = parse_line(r#"{"op":"chain","method":"f64","d":9,"steps":17,"seed":3}"#)
            .unwrap();
        let scan = random_scan_req(21, 4, 3);
        for req in [chain, scan] {
            let frame = encode_request_frame(&req, None);
            let (bin_req, _) = decode_frame(&frame).unwrap();
            assert_eq!(bin_req.canonical_key(), req.canonical_key());
            assert_eq!(bin_req.canonical_line(), req.canonical_line());
            // And the JSON spelling of the canonical form parses back to
            // the same request — both wires name one cache entry.
            let twin = parse_line(&req.canonical_line().unwrap()).unwrap();
            assert_eq!(bin_req, twin);
        }
    }

    #[test]
    fn binary_decode_enforces_the_same_bounds_as_json() {
        // Each case: mutate one field of a valid frame, expect an error
        // whose text matches the JSON-side rejection family.
        let check = |req: &Request, mutate: &dyn Fn(&mut Vec<u8>), needle: &str| {
            let mut frame = encode_request_frame(req, None);
            mutate(&mut frame);
            let err = decode_request_frame(&frame[FRAME_HEADER..]).unwrap_err();
            assert!(err.contains(needle), "want '{needle}' in '{err}'");
        };
        let chain = Request::Chain(ChainReq {
            method: Method::GoomC64,
            d: 8,
            steps: 1000,
            seed: 42,
        });
        // d = 2048 > MAX_CHAIN_D (offset: header 8 + tag 1 + id_len 4 + op 1 + method 1).
        check(&chain, &|f| f[15..19].copy_from_slice(&2048u32.to_le_bytes()), "'d' = 2048");
        // steps over MAX_CHAIN_STEPS.
        check(
            &chain,
            &|f| f[19..27].copy_from_slice(&300_000u64.to_le_bytes()),
            "'steps' = 300000",
        );
        // seed = 2^53 (first non-exact integer).
        check(
            &chain,
            &|f| f[27..35].copy_from_slice(&9_007_199_254_740_992u64.to_le_bytes()),
            "'seed' must be an integer in [0, 2^53)",
        );
        // Work budget: d=1024 at steps=1000 blows d³·steps.
        check(&chain, &|f| f[15..19].copy_from_slice(&1024u32.to_le_bytes()), "exceeds the budget");
        // Unknown method tag.
        check(&chain, &|f| f[14] = 9, "unknown method tag 9");
        // Unknown op tag.
        check(&chain, &|f| f[13] = 0, "unknown op tag 0");
        // Trailing garbage after a fixed-size body is rejected, not ignored.
        check(
            &chain,
            &|f| {
                f.push(0);
                let len = (f.len() - FRAME_HEADER) as u32;
                f[4..8].copy_from_slice(&len.to_le_bytes());
            },
            "trailing bytes",
        );
        // Scan: NaN logmag (JSON has no literal for it) and sign ≠ ±1.
        let scan = random_scan_req(5, 2, 1);
        let sign_err = decode_frame(&{
            let Request::Scan(s) = &scan else { unreachable!() };
            let mut bad = s.clone();
            bad.mats[0].sign[2] = 0.5;
            encode_request_frame(&Request::Scan(bad), None)
        })
        .unwrap_err();
        assert!(sign_err.contains("must be 1 or -1"), "{sign_err}");
        let nan_err = decode_frame(&{
            let Request::Scan(s) = &scan else { unreachable!() };
            let mut bad = s.clone();
            bad.mats[0].logmag[1] = f64::NAN;
            encode_request_frame(&Request::Scan(bad), None)
        })
        .unwrap_err();
        assert!(nan_err.contains("not a number"), "{nan_err}");
        // +inf is not a GOOM value either (JSON could never have said it).
        let inf_err = decode_frame(&{
            let Request::Scan(s) = &scan else { unreachable!() };
            let mut bad = s.clone();
            bad.mats[0].logmag[0] = f64::INFINITY;
            encode_request_frame(&Request::Scan(bad), None)
        })
        .unwrap_err();
        assert!(inf_err.contains("not a number"), "{inf_err}");
    }

    #[test]
    fn every_truncation_of_a_request_frame_payload_errors() {
        for req in [
            Request::Chain(ChainReq { method: Method::F32, d: 4, steps: 10, seed: 1 }),
            random_scan_req(31, 2, 2),
            Request::Trace { limit: 5 },
        ] {
            let frame = encode_request_frame(&req, Some(&Json::Num(3.0)));
            let payload = &frame[FRAME_HEADER..];
            for cut in 0..payload.len() {
                assert!(
                    decode_request_frame(&payload[..cut]).is_err(),
                    "cut at {cut}/{} must error",
                    payload.len()
                );
            }
        }
    }

    #[test]
    fn response_frames_decode_to_the_json_twin_document() {
        // Generic (chain-shaped) result, miss then hit.
        let result = obj(vec![
            ("d", num(8.0)),
            ("final_max_logmag", num(123.456)),
            ("failed", Json::Bool(false)),
            ("dynamic_range_decades", Json::Null),
        ]);
        for cached in [false, true] {
            let frame = encode_ok_frame(&result, cached, RespKind::Generic, None);
            let doc = decode_response_frame(&frame[FRAME_HEADER..]).unwrap();
            assert_eq!(doc, json::parse(&ok_line(result.clone(), cached)).unwrap());
        }
        // Scan result rides gbin tensors yet decodes to the same document,
        // GOOM zeros (`null`) included.
        let scan_result = obj(vec![
            ("d", num(2.0)),
            ("len", num(3.0)),
            ("logmag", Json::Arr(vec![num(1.5), Json::Null, num(-2.0), num(0.0)])),
            ("sign", Json::Arr(vec![num(1.0), num(1.0), num(-1.0), num(1.0)])),
            ("log_frobenius", num(4.25)),
        ]);
        let frame = encode_ok_frame(&scan_result, false, RespKind::Scan, None);
        let doc = decode_response_frame(&frame[FRAME_HEADER..]).unwrap();
        assert_eq!(doc, json::parse(&ok_line(scan_result.clone(), false)).unwrap());
        // A scan-kind result that is not scan-shaped falls back to JSON text.
        let odd = obj(vec![("x", num(1.0))]);
        let frame = encode_ok_frame(&odd, false, RespKind::Scan, None);
        let doc = decode_response_frame(&frame[FRAME_HEADER..]).unwrap();
        assert_eq!(doc, json::parse(&ok_line(odd, false)).unwrap());
        // Errors, with and without retry_after_ms.
        for retry in [None, Some(250)] {
            let frame = encode_err_frame("server busy: no", retry, None);
            let doc = decode_response_frame(&frame[FRAME_HEADER..]).unwrap();
            assert_eq!(doc, json::parse(&err_line("server busy: no", retry)).unwrap());
        }
    }

    #[test]
    fn frame_with_id_matches_encoding_the_id_directly() {
        let result = obj(vec![("v", num(9.0))]);
        let bare = encode_ok_frame(&result, true, RespKind::Generic, None);
        for id in [Json::Str("abc".into()), Json::Num(12.0)] {
            let spliced = frame_with_id(&bare, &id);
            let direct = encode_ok_frame(&result, true, RespKind::Generic, Some(&id));
            assert_eq!(spliced, direct);
            let doc = decode_response_frame(&spliced[FRAME_HEADER..]).unwrap();
            assert_eq!(doc.get("id"), Some(&id));
        }
        // Splicing over an existing id replaces it.
        let twice = frame_with_id(&frame_with_id(&bare, &Json::Num(1.0)), &Json::Num(2.0));
        let doc = decode_response_frame(&twice[FRAME_HEADER..]).unwrap();
        assert_eq!(doc.get("id"), Some(&Json::Num(2.0)));
        // And the Rendered fan-out path agrees with both single encoders.
        let r = Rendered::ok(&result, true, RespKind::Generic);
        let id = Json::Num(5.0);
        let direct = encode_ok_frame(&result, true, RespKind::Generic, Some(&id));
        match r.to_payload(Wire::Binary, Some(&id)) {
            Payload::Bin(b) => assert_eq!(&b[..], &direct[..]),
            other => panic!("wrong payload kind {other:?}"),
        }
        match r.to_payload(Wire::Json, Some(&id)) {
            Payload::Json(s) => {
                assert_eq!(&s[..], attach_id(&ok_line(result.clone(), true), &id))
            }
            other => panic!("wrong payload kind {other:?}"),
        }
    }

    #[test]
    fn scan_wire_classifies_mixed_buffers() {
        // Ambiguous magic prefixes need more bytes.
        for p in [&b""[..], b"G", b"GB", b"GBF"] {
            assert_eq!(scan_wire(p), FrameScan::NeedMore, "{p:?}");
        }
        // Anything diverging from the magic is line-framed.
        assert_eq!(scan_wire(b"{\"op\":\"info\"}"), FrameScan::NeedMore);
        assert_eq!(scan_wire(b"{\"op\":\"info\"}\n"), FrameScan::Line { nl: 13 });
        assert_eq!(scan_wire(b"GBX corrupt\n"), FrameScan::Line { nl: 11 });
        assert_eq!(scan_wire(b"GBFX\n"), FrameScan::Line { nl: 4 });
        // Frame header declares the payload; completeness is byte-exact.
        let frame = encode_request_frame(&Request::Info, None);
        assert_eq!(scan_wire(&frame[..4]), FrameScan::NeedMore);
        assert_eq!(scan_wire(&frame[..7]), FrameScan::NeedMore);
        let len = frame.len() - FRAME_HEADER;
        assert_eq!(scan_wire(&frame[..8]), FrameScan::PartialFrame { len });
        assert_eq!(scan_wire(&frame[..frame.len() - 1]), FrameScan::PartialFrame { len });
        assert_eq!(scan_wire(&frame), FrameScan::Frame { total: frame.len() });
        // Trailing bytes past one frame don't change the classification.
        let mut two = frame.clone();
        two.extend_from_slice(b"{\"op\":\"info\"}\n");
        assert_eq!(scan_wire(&two), FrameScan::Frame { total: frame.len() });
    }
}
