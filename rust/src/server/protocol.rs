//! `goomd` wire protocol: newline-delimited JSON over TCP.
//!
//! Every request is one JSON object on one line; every response is one JSON
//! object on one line. Requests select an operation with `"op"`:
//!
//! ```text
//! {"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}
//! {"op":"scan","d":2,"logmag":[[0,null,null,0]],"sign":[[1,1,1,1]],"chunks":16}
//! {"op":"lle","system":"lorenz","steps":4000,"burn":1000,"chunks":64}
//! {"op":"info"}
//! {"op":"metrics"}
//! {"op":"trace","limit":256}
//! ```
//!
//! Responses are `{"ok":true,"cached":…,"result":{…}}` or
//! `{"ok":false,"error":"…"}` (with `"retry_after_ms"` when the server is
//! shedding load and the client should back off and retry).
//!
//! Any request may carry an optional `"id"` (string or integer): it is
//! echoed verbatim as the first key of the response line, forwarded
//! router → shard so cross-tier traces stitch on it, and — while tracing
//! is sampled on (`--trace-sample`) — it forces the request to be traced
//! (see [`crate::obs`]). The `id` is *not* part of the canonical form:
//! cache identity and rendezvous routing ignore it.
//!
//! GOOM zeros (logmag = -inf) have no JSON literal; the protocol encodes
//! them as `null` in `logmag` arrays, both directions.
//!
//! Decoding validates *shape and bounds* here; semantic checks that need
//! the wider library (e.g. whether a dynamical system exists) happen at
//! execution so this module stays dependency-light and unit-testable.

use crate::chain::Method;
use crate::goom::GoomMat;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// Hard per-request bounds: a single request must never be able to pin a
/// worker for unbounded time or memory.
///
/// `MAX_CHAIN_D` was 128 while the kernel packed full-depth panels (they
/// had to fit L2); the `KC` depth loop (`goom::kernel`) keeps panels
/// cache-resident at any dimension, so the cap is now a memory/time bound
/// only. Raising it must not raise the worst-case *time* one request can
/// pin a worker, so `d` and `steps` are additionally bound jointly by
/// [`MAX_CHAIN_WORK`] (one chain step costs ~2·d³ FLOPs). `MAX_SCAN_D`
/// stays payload-bound: scan operands travel in the request body as JSON,
/// so the line-size cap is the real limit there.
pub const MAX_CHAIN_D: usize = 1024;
/// Joint chain budget: `d³ · steps` may not exceed what the pre-KC caps
/// allowed at their combined worst case (128³ · 200 000) — e.g. `d = 1024`
/// is served up to ~390 steps, `d = 512` up to ~3 100.
pub const MAX_CHAIN_WORK: u128 = 128u128.pow(3) * 200_000;
pub const MAX_CHAIN_STEPS: usize = 200_000;
pub const MAX_SCAN_D: usize = 64;
pub const MAX_SCAN_LEN: usize = 4096;
pub const MAX_LLE_STEPS: usize = 200_000;
pub const MAX_LLE_BURN: usize = 1_000_000;
pub const MAX_CHUNKS: usize = 4096;
/// Bound on the `trace` op's span count (well past every ring's capacity).
pub const MAX_TRACE_LIMIT: usize = 100_000;

/// A decoded, bounds-checked request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Chain(ChainReq),
    Scan(ScanReq),
    Lle(LleReq),
    Info,
    Metrics,
    /// Recent trace spans (most recent `limit`), newest last.
    Trace { limit: usize },
}

/// Fig.-1 matrix-product chain over any served [`Method`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainReq {
    pub method: Method,
    pub d: usize,
    pub steps: usize,
    pub seed: u64,
}

/// Prefix scan (cumulative `S_t = A_t · S_{t-1}`) over client-supplied GOOM
/// transition matrices. The response carries the final state.
#[derive(Debug, Clone, PartialEq)]
pub struct ScanReq {
    pub d: usize,
    pub mats: Vec<GoomMat<f64>>,
    pub chunks: usize,
}

/// Largest-Lyapunov-exponent estimate for a registered `dynsys` system.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LleReq {
    pub system: String,
    pub steps: usize,
    pub burn: usize,
    pub chunks: usize,
}

/// Canonical lowercase slug for a method (stable across releases — part of
/// the wire protocol and the cache key).
pub fn method_slug(m: Method) -> &'static str {
    match m {
        Method::F32 => "f32",
        Method::F64 => "f64",
        Method::GoomC64 => "goomc64",
        Method::GoomC128 => "goomc128",
        Method::GoomHlo => "goomhlo",
    }
}

// ---------------------------------------------------------------- decode --

fn bounded_usize(
    doc: &Json,
    key: &str,
    default: usize,
    min: usize,
    max: usize,
) -> Result<usize, String> {
    let v = match doc.get(key) {
        None => return Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| format!("'{key}' must be a non-negative integer"))?,
    };
    if v < min || v > max {
        return Err(format!("'{key}' = {v} out of range [{min}, {max}]"));
    }
    Ok(v)
}

fn seed_field(doc: &Json, default: u64) -> Result<u64, String> {
    match doc.get("seed") {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or("'seed' must be a number")?;
            if x < 0.0 || x.fract() != 0.0 || x >= 9_007_199_254_740_992.0 {
                return Err("'seed' must be an integer in [0, 2^53)".to_string());
            }
            Ok(x as u64)
        }
    }
}

impl Request {
    /// Decode and bounds-check one request document.
    pub fn parse(doc: &Json) -> Result<Request, String> {
        let op = doc
            .get("op")
            .and_then(Json::as_str)
            .ok_or("missing string field 'op'")?;
        match op {
            "info" => Ok(Request::Info),
            "metrics" => Ok(Request::Metrics),
            "trace" => Ok(Request::Trace {
                limit: bounded_usize(
                    doc,
                    "limit",
                    crate::obs::DEFAULT_TRACE_LIMIT,
                    1,
                    MAX_TRACE_LIMIT,
                )?,
            }),
            "chain" => Self::parse_chain(doc),
            "scan" => Self::parse_scan(doc),
            "lle" => Self::parse_lle(doc),
            other => Err(format!(
                "unknown op '{other}' (expected chain|scan|lle|info|metrics|trace)"
            )),
        }
    }

    fn parse_chain(doc: &Json) -> Result<Request, String> {
        let method_str = doc
            .get("method")
            .map(|v| v.as_str().ok_or("'method' must be a string"))
            .transpose()?
            .unwrap_or("goomc64");
        let method = Method::parse(method_str)
            .ok_or_else(|| format!("unknown method '{method_str}'"))?;
        if method == Method::GoomHlo {
            return Err(
                "method 'goomhlo' needs the AOT/PJRT engine and is not served; \
                 use goomc64/goomc128"
                    .to_string(),
            );
        }
        let d = bounded_usize(doc, "d", 8, 1, MAX_CHAIN_D)?;
        let steps = bounded_usize(doc, "steps", 1000, 0, MAX_CHAIN_STEPS)?;
        let work = (d as u128).pow(3) * steps as u128;
        if work > MAX_CHAIN_WORK {
            return Err(format!(
                "chain work d^3*steps = {work} exceeds the budget {MAX_CHAIN_WORK}; \
                 reduce 'steps' at large 'd'"
            ));
        }
        Ok(Request::Chain(ChainReq { method, d, steps, seed: seed_field(doc, 42)? }))
    }

    fn parse_scan(doc: &Json) -> Result<Request, String> {
        let d = bounded_usize(doc, "d", 0, 1, MAX_SCAN_D)?;
        if d == 0 {
            return Err("scan requires 'd' (matrix dimension)".to_string());
        }
        let logmag = doc
            .get("logmag")
            .and_then(Json::as_arr)
            .ok_or("scan requires 'logmag': array of arrays")?;
        let sign = doc
            .get("sign")
            .and_then(Json::as_arr)
            .ok_or("scan requires 'sign': array of arrays")?;
        if logmag.is_empty() {
            return Err("'logmag' must hold at least one matrix".to_string());
        }
        if logmag.len() > MAX_SCAN_LEN {
            return Err(format!(
                "'logmag' holds {} matrices (max {MAX_SCAN_LEN})",
                logmag.len()
            ));
        }
        if sign.len() != logmag.len() {
            return Err(format!(
                "'sign' holds {} matrices but 'logmag' holds {}",
                sign.len(),
                logmag.len()
            ));
        }
        let mut mats = Vec::with_capacity(logmag.len());
        for (t, (lm, sg)) in logmag.iter().zip(sign.iter()).enumerate() {
            let lm = lm
                .as_arr()
                .ok_or_else(|| format!("logmag[{t}] is not an array"))?;
            let sg = sg
                .as_arr()
                .ok_or_else(|| format!("sign[{t}] is not an array"))?;
            if lm.len() != d * d || sg.len() != d * d {
                return Err(format!(
                    "matrix {t}: expected {} entries (d={d}), got logmag {} / sign {}",
                    d * d,
                    lm.len(),
                    sg.len()
                ));
            }
            let mut m = GoomMat::<f64>::zeros(d, d);
            for (i, (l, s)) in lm.iter().zip(sg.iter()).enumerate() {
                m.logmag[i] = match l {
                    Json::Null => f64::NEG_INFINITY, // GOOM zero
                    other => other
                        .as_f64()
                        .ok_or_else(|| format!("logmag[{t}][{i}] not a number"))?,
                };
                let s = s
                    .as_f64()
                    .ok_or_else(|| format!("sign[{t}][{i}] not a number"))?;
                if s != 1.0 && s != -1.0 {
                    return Err(format!("sign[{t}][{i}] must be 1 or -1, got {s}"));
                }
                m.sign[i] = s;
            }
            mats.push(m);
        }
        Ok(Request::Scan(ScanReq {
            d,
            mats,
            chunks: bounded_usize(doc, "chunks", 16, 1, MAX_CHUNKS)?,
        }))
    }

    fn parse_lle(doc: &Json) -> Result<Request, String> {
        let system = doc
            .get("system")
            .and_then(Json::as_str)
            .ok_or("lle requires string field 'system'")?
            .to_ascii_lowercase();
        Ok(Request::Lle(LleReq {
            system,
            steps: bounded_usize(doc, "steps", 4000, 1, MAX_LLE_STEPS)?,
            burn: bounded_usize(doc, "burn", 1000, 0, MAX_LLE_BURN)?,
            chunks: bounded_usize(doc, "chunks", 64, 1, MAX_CHUNKS)?,
        }))
    }

    /// Canonical wire form: the request re-encoded with every default made
    /// explicit, keys sorted (the JSON writer emits `BTreeMap` order).
    /// Always a parseable request line — the router forwards this instead
    /// of the client's spelling, so shards see normalized traffic. `None`
    /// for the introspection ops.
    pub fn canonical_line(&self) -> Option<String> {
        let doc = match self {
            Request::Info | Request::Metrics | Request::Trace { .. } => return None,
            Request::Chain(c) => obj(vec![
                ("op", Json::Str("chain".into())),
                ("method", Json::Str(method_slug(c.method).into())),
                ("d", num(c.d as f64)),
                ("steps", num(c.steps as f64)),
                ("seed", num(c.seed as f64)),
            ]),
            Request::Scan(s) => obj(vec![
                ("op", Json::Str("scan".into())),
                ("d", num(s.d as f64)),
                ("chunks", num(s.chunks as f64)),
                (
                    "logmag",
                    Json::Arr(
                        s.mats
                            .iter()
                            .map(|m| {
                                Json::Arr(
                                    m.logmag.iter().copied().map(num_or_null).collect(),
                                )
                            })
                            .collect(),
                    ),
                ),
                (
                    "sign",
                    Json::Arr(
                        s.mats
                            .iter()
                            .map(|m| {
                                Json::Arr(m.sign.iter().map(|&x| num(x)).collect())
                            })
                            .collect(),
                    ),
                ),
            ]),
            Request::Lle(l) => obj(vec![
                ("op", Json::Str("lle".into())),
                ("system", Json::Str(l.system.clone())),
                ("steps", num(l.steps as f64)),
                ("burn", num(l.burn as f64)),
                ("chunks", num(l.chunks as f64)),
            ]),
        };
        Some(json::write(&doc))
    }

    /// Canonical cache key: [`canonical_line`](Self::canonical_line), with
    /// large canonical forms (scan payloads run to `max_request_bytes`)
    /// digested to a fixed-size key so the entry-count LRU cannot be made
    /// to retain gigabytes of key strings. `None` for the introspection
    /// ops, which are never cached.
    pub fn canonical_key(&self) -> Option<String> {
        let full = self.canonical_line()?;
        Some(if full.len() > MAX_VERBATIM_KEY_BYTES {
            digest_key(&full)
        } else {
            full
        })
    }

    /// Pool batch key: requests sharing a key may be executed together in
    /// one stacked pass. GOOM chain requests batch by (method, d) — they
    /// share the per-step LMME — and scan requests batch by dimension,
    /// advancing their chunked folds in lockstep. Float chains and LLE
    /// run solo.
    pub fn batch_key(&self) -> Option<String> {
        match self {
            Request::Chain(c)
                if c.method == Method::GoomC64 || c.method == Method::GoomC128 =>
            {
                Some(format!("chain:{}:{}", method_slug(c.method), c.d))
            }
            Request::Scan(s) => Some(format!("scan:{}", s.d)),
            _ => None,
        }
    }

    /// Admission cost in the [`MAX_CHAIN_WORK`] currency (`d³ · steps` —
    /// each chain step is one d×d LMME at ~2·d³ FLOPs). Scans charge one
    /// d×d combine per supplied matrix; LLE runs on tiny (≈3-dim) tangent
    /// systems, so each step is charged at the smallest cube that bounds
    /// it. Introspection ops are free — they never reach the pool.
    pub fn work_units(&self) -> u128 {
        match self {
            Request::Chain(c) => (c.d as u128).pow(3) * c.steps as u128,
            Request::Scan(s) => (s.d as u128).pow(3) * s.mats.len() as u128,
            Request::Lle(l) => 27 * (l.steps + l.burn) as u128,
            Request::Info | Request::Metrics | Request::Trace { .. } => 0,
        }
    }
}

/// Canonical keys longer than this are replaced by a 128-bit digest
/// (2×64-bit SipHash with distinct prefixes, plus the original length).
/// Accidental collisions are negligible at cache scale; the daemon is not
/// hardened against adversarial collision construction.
const MAX_VERBATIM_KEY_BYTES: usize = 4096;

fn digest_key(full: &str) -> String {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h1 = DefaultHasher::new();
    0u8.hash(&mut h1);
    full.hash(&mut h1);
    let mut h2 = DefaultHasher::new();
    1u8.hash(&mut h2);
    full.hash(&mut h2);
    format!("digest:{}:{:016x}{:016x}", full.len(), h1.finish(), h2.finish())
}

// ---------------------------------------------------------------- encode --

/// Build a JSON object from pairs (convenience for response assembly).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Shorthand for a JSON number.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// JSON has no ±inf/NaN: encode non-finite magnitudes as `null` (the GOOM
/// zero convention on the wire).
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// A success response line (no trailing newline).
pub fn ok_line(result: Json, cached: bool) -> String {
    json::write(&obj(vec![
        ("ok", Json::Bool(true)),
        ("cached", Json::Bool(cached)),
        ("result", result),
    ]))
}

/// An error response line (no trailing newline). `retry_after_ms` marks
/// load-shedding rejections the client should retry after backing off.
pub fn err_line(msg: &str, retry_after_ms: Option<u64>) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ];
    if let Some(ms) = retry_after_ms {
        pairs.push(("retry_after_ms", num(ms as f64)));
    }
    json::write(&obj(pairs))
}

/// Cap on a client-supplied `id`'s serialized form: ids are echoed on
/// every response and copied into trace spans, so they must stay small.
pub const MAX_ID_BYTES: usize = 256;

/// Validate the optional request `id`: absent, a string, or an integer in
/// `[0, 2^53)` (the range the JSON writer reproduces exactly). Anything
/// else is a protocol error — silently dropping a malformed id would break
/// the client's response matching.
pub fn parse_id(doc: &Json) -> Result<Option<Json>, String> {
    match doc.get("id") {
        None => Ok(None),
        Some(Json::Str(s)) => {
            if s.len() > MAX_ID_BYTES {
                return Err(format!("'id' exceeds {MAX_ID_BYTES} bytes"));
            }
            Ok(Some(Json::Str(s.clone())))
        }
        Some(Json::Num(x)) => {
            if *x < 0.0 || x.fract() != 0.0 || *x >= 9_007_199_254_740_992.0 {
                return Err("'id' must be a string or an integer in [0, 2^53)".to_string());
            }
            Ok(Some(Json::Num(*x)))
        }
        Some(_) => Err("'id' must be a string or an integer".to_string()),
    }
}

/// Splice the echoed `id` onto a finished response line as its first key.
/// Response lines are single JSON objects, so prefix insertion keeps the
/// body byte-identical — crucially, a shard-computed line fanned to many
/// coalesced waiters gets each waiter's own id without re-serializing the
/// result. Non-object lines (impossible today) pass through unchanged.
pub fn attach_id(line: &str, id: &Json) -> String {
    let Some(rest) = line.strip_prefix('{') else {
        return line.to_string();
    };
    let id_txt = json::write(id);
    if rest.starts_with('}') {
        format!("{{\"id\":{id_txt}{rest}")
    } else {
        format!("{{\"id\":{id_txt},{rest}")
    }
}

/// Client-side encoder for a chain request (used by `repro loadgen` and the
/// round-trip tests).
pub fn encode_chain_request(method: &str, d: usize, steps: usize, seed: u64) -> String {
    json::write(&obj(vec![
        ("op", Json::Str("chain".into())),
        ("method", Json::Str(method.to_string())),
        ("d", num(d as f64)),
        ("steps", num(steps as f64)),
        ("seed", num(seed as f64)),
    ]))
}

/// Client-side encoder for a scan request over real-valued matrices
/// (log-mapped on the client; mirrors `GoomMat::from_mat`).
pub fn encode_scan_request(mats: &[GoomMat<f64>], chunks: usize) -> String {
    let d = mats.first().map_or(0, |m| m.rows);
    json::write(&obj(vec![
        ("op", Json::Str("scan".into())),
        ("d", num(d as f64)),
        ("chunks", num(chunks as f64)),
        (
            "logmag",
            Json::Arr(
                mats.iter()
                    .map(|m| {
                        Json::Arr(m.logmag.iter().copied().map(num_or_null).collect())
                    })
                    .collect(),
            ),
        ),
        (
            "sign",
            Json::Arr(
                mats.iter()
                    .map(|m| Json::Arr(m.sign.iter().map(|&x| num(x)).collect()))
                    .collect(),
            ),
        ),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    fn parse_line(line: &str) -> Result<Request, String> {
        let doc = json::parse(line).map_err(|e| e.to_string())?;
        Request::parse(&doc)
    }

    #[test]
    fn chain_request_round_trips_through_encode_and_parse() {
        let line = encode_chain_request("goomc128", 16, 5000, 7);
        let req = parse_line(&line).unwrap();
        assert_eq!(
            req,
            Request::Chain(ChainReq {
                method: Method::GoomC128,
                d: 16,
                steps: 5000,
                seed: 7
            })
        );
        // Canonical key is itself parseable and stable.
        let key = req.canonical_key().unwrap();
        let req2 = parse_line(&key).unwrap();
        assert_eq!(req, req2);
        assert_eq!(key, req2.canonical_key().unwrap());
    }

    #[test]
    fn chain_defaults_are_canonicalized_into_the_key() {
        // A request relying on defaults and one spelling them out must map
        // to the same cache key.
        let implicit = parse_line(r#"{"op":"chain"}"#).unwrap();
        let explicit =
            parse_line(r#"{"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}"#)
                .unwrap();
        assert_eq!(implicit.canonical_key(), explicit.canonical_key());
    }

    #[test]
    fn scan_request_round_trips_with_goom_zeros() {
        let mut rng = rng_from_seed(90);
        let mut mats: Vec<GoomMat<f64>> =
            (0..3).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        mats[1].logmag[2] = f64::NEG_INFINITY; // a GOOM zero → null on the wire
        let line = encode_scan_request(&mats, 4);
        let Request::Scan(s) = parse_line(&line).unwrap() else {
            panic!("not a scan")
        };
        assert_eq!(s.d, 2);
        assert_eq!(s.chunks, 4);
        assert_eq!(s.mats, mats);
    }

    #[test]
    fn rejects_malformed_and_out_of_bounds() {
        assert!(parse_line("42").is_err());
        assert!(parse_line(r#"{"no_op":1}"#).is_err());
        assert!(parse_line(r#"{"op":"fry"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","method":"quantum"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","method":"hlo"}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","d":0}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","d":10000}"#).is_err());
        // The KC kernel lifted the old d ≤ 128 serving cap: dimensions up
        // to MAX_CHAIN_D now decode, but d and steps are jointly bounded
        // by the work budget so one request still cannot pin a worker for
        // longer than the pre-KC worst case.
        assert!(parse_line(r#"{"op":"chain","d":512}"#).is_ok());
        assert!(parse_line(
            &format!(r#"{{"op":"chain","d":{MAX_CHAIN_D},"steps":200}}"#)
        )
        .is_ok());
        assert!(parse_line(
            &format!(r#"{{"op":"chain","d":{},"steps":200}}"#, MAX_CHAIN_D + 1)
        )
        .is_err());
        assert!(
            parse_line(r#"{"op":"chain","d":1024,"steps":5000}"#).is_err(),
            "over the d^3*steps budget"
        );
        // At d = 128 the full historical step range still decodes.
        assert!(parse_line(r#"{"op":"chain","d":128,"steps":200000}"#).is_ok());
        assert!(parse_line(r#"{"op":"chain","steps":99999999}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","seed":-1}"#).is_err());
        assert!(parse_line(r#"{"op":"chain","seed":1.5}"#).is_err());
        assert!(parse_line(r#"{"op":"lle","steps":10}"#).is_err()); // no system
        assert!(parse_line(r#"{"op":"scan","d":2}"#).is_err()); // no payload
        assert!(
            parse_line(r#"{"op":"scan","d":2,"logmag":[[0,0,0,0]],"sign":[[1,2,1,1]]}"#)
                .is_err(),
            "non-±1 sign must be rejected"
        );
        assert!(
            parse_line(r#"{"op":"scan","d":2,"logmag":[[0,0,0]],"sign":[[1,1,1]]}"#)
                .is_err(),
            "wrong entry count must be rejected"
        );
    }

    #[test]
    fn large_scan_payloads_get_fixed_size_digest_keys() {
        let mut rng = rng_from_seed(91);
        // 32 8x8 matrices serialize far past the 4 KiB verbatim-key cap.
        let mats: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let line = encode_scan_request(&mats, 8);
        let req = parse_line(&line).unwrap();
        let key = req.canonical_key().unwrap();
        assert!(key.starts_with("digest:"), "expected digest key, got {} bytes", key.len());
        assert!(key.len() < 128, "digest keys must stay small: {}", key.len());
        // Deterministic for identical payloads, distinct for different ones.
        assert_eq!(key, parse_line(&line).unwrap().canonical_key().unwrap());
        let other: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let other_key =
            parse_line(&encode_scan_request(&other, 8)).unwrap().canonical_key().unwrap();
        assert_ne!(key, other_key);
        // Small requests keep their verbatim (parseable) canonical form.
        let small = parse_line(r#"{"op":"chain"}"#).unwrap();
        assert!(!small.canonical_key().unwrap().starts_with("digest:"));
    }

    #[test]
    fn batch_keys_group_same_shape_goom_chains_and_scans() {
        let a = parse_line(r#"{"op":"chain","method":"goomc64","d":8}"#).unwrap();
        let b = parse_line(r#"{"op":"chain","method":"goomc64","d":8,"seed":9}"#).unwrap();
        let c = parse_line(r#"{"op":"chain","method":"goomc64","d":16}"#).unwrap();
        let d = parse_line(r#"{"op":"chain","method":"f64","d":8}"#).unwrap();
        let e = parse_line(r#"{"op":"lle","system":"lorenz"}"#).unwrap();
        assert_eq!(a.batch_key(), b.batch_key());
        assert!(a.batch_key().is_some());
        assert_ne!(a.batch_key(), c.batch_key());
        assert_eq!(d.batch_key(), None);
        assert_eq!(e.batch_key(), None);
        // Same-dimension scans share a batch key regardless of payload;
        // other dimensions do not.
        let mut rng = rng_from_seed(5);
        let m2: Vec<GoomMat<f64>> =
            (0..2).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let n2: Vec<GoomMat<f64>> =
            (0..4).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let m3: Vec<GoomMat<f64>> =
            (0..2).map(|_| GoomMat::randn(3, 3, &mut rng)).collect();
        let s2 = parse_line(&encode_scan_request(&m2, 4)).unwrap();
        let t2 = parse_line(&encode_scan_request(&n2, 8)).unwrap();
        let s3 = parse_line(&encode_scan_request(&m3, 4)).unwrap();
        assert_eq!(s2.batch_key(), t2.batch_key());
        assert!(s2.batch_key().is_some());
        assert_ne!(s2.batch_key(), s3.batch_key());
        assert_ne!(s2.batch_key(), a.batch_key());
    }

    #[test]
    fn work_units_charge_in_the_chain_budget_currency() {
        let big = parse_line(r#"{"op":"chain","d":128,"steps":200000}"#).unwrap();
        assert_eq!(big.work_units(), MAX_CHAIN_WORK, "ceiling chain = full budget");
        let small = parse_line(r#"{"op":"chain","d":8,"steps":1000}"#).unwrap();
        assert_eq!(small.work_units(), 512 * 1000);
        assert!(big.work_units() > 100_000 * small.work_units() / 128);
        let mut rng = rng_from_seed(3);
        let mats: Vec<GoomMat<f64>> =
            (0..3).map(|_| GoomMat::randn(2, 2, &mut rng)).collect();
        let scan = parse_line(&encode_scan_request(&mats, 4)).unwrap();
        assert_eq!(scan.work_units(), 8 * 3);
        let lle = parse_line(r#"{"op":"lle","system":"lorenz","steps":100,"burn":50}"#)
            .unwrap();
        assert_eq!(lle.work_units(), 27 * 150);
        assert_eq!(Request::Info.work_units(), 0);
        assert_eq!(Request::Metrics.work_units(), 0);
    }

    #[test]
    fn canonical_line_is_always_a_parseable_normalized_request() {
        // Even when the cache key degrades to a digest (large scans), the
        // canonical line the router forwards stays a full request.
        let mut rng = rng_from_seed(92);
        let mats: Vec<GoomMat<f64>> =
            (0..32).map(|_| GoomMat::randn(8, 8, &mut rng)).collect();
        let req = parse_line(&encode_scan_request(&mats, 8)).unwrap();
        assert!(req.canonical_key().unwrap().starts_with("digest:"));
        let line = req.canonical_line().unwrap();
        assert_eq!(parse_line(&line).unwrap(), req, "line must round-trip");
        // Defaults are spelled out, so distinct spellings converge.
        let implicit = parse_line(r#"{"op":"chain"}"#).unwrap();
        let explicit = parse_line(
            r#"{"op":"chain","method":"goomc64","d":8,"steps":1000,"seed":42}"#,
        )
        .unwrap();
        assert_eq!(implicit.canonical_line(), explicit.canonical_line());
        assert_eq!(Request::Info.canonical_line(), None);
    }

    #[test]
    fn response_lines_are_valid_json() {
        let ok = ok_line(obj(vec![("x", num(1.0))]), true);
        let parsed = json::parse(&ok).unwrap();
        assert_eq!(parsed.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("cached").unwrap().as_bool(), Some(true));
        let err = err_line("queue full", Some(250));
        let parsed = json::parse(&err).unwrap();
        assert_eq!(parsed.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(parsed.get("retry_after_ms").unwrap().as_usize(), Some(250));
        // Non-finite numbers must never leak into the wire format.
        assert_eq!(num_or_null(f64::NEG_INFINITY), Json::Null);
        assert_eq!(num_or_null(f64::NAN), Json::Null);
    }

    #[test]
    fn info_and_metrics_parse_and_are_uncached() {
        assert_eq!(parse_line(r#"{"op":"info"}"#).unwrap(), Request::Info);
        assert_eq!(parse_line(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(Request::Info.canonical_key(), None);
        assert_eq!(Request::Metrics.canonical_key(), None);
        assert_eq!(Request::Info.batch_key(), None);
    }

    #[test]
    fn trace_op_parses_with_bounded_limit_and_is_uncached() {
        assert_eq!(
            parse_line(r#"{"op":"trace"}"#).unwrap(),
            Request::Trace { limit: crate::obs::DEFAULT_TRACE_LIMIT }
        );
        assert_eq!(
            parse_line(r#"{"op":"trace","limit":32}"#).unwrap(),
            Request::Trace { limit: 32 }
        );
        assert!(parse_line(r#"{"op":"trace","limit":0}"#).is_err());
        assert!(parse_line(r#"{"op":"trace","limit":99999999}"#).is_err());
        let t = Request::Trace { limit: 8 };
        assert_eq!(t.canonical_key(), None, "trace answers are never cached");
        assert_eq!(t.canonical_line(), None);
        assert_eq!(t.batch_key(), None);
    }

    #[test]
    fn id_field_validates_and_canonical_forms_ignore_it() {
        let doc = json::parse(r#"{"op":"chain","id":"req-9"}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), Some(Json::Str("req-9".into())));
        let doc = json::parse(r#"{"op":"chain","id":42}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), Some(Json::Num(42.0)));
        let doc = json::parse(r#"{"op":"chain"}"#).unwrap();
        assert_eq!(parse_id(&doc).unwrap(), None);
        for bad in [
            r#"{"id":true}"#,
            r#"{"id":[1]}"#,
            r#"{"id":1.5}"#,
            r#"{"id":-3}"#,
        ] {
            let doc = json::parse(bad).unwrap();
            assert!(parse_id(&doc).is_err(), "{bad} must be rejected");
        }
        // The id never reaches cache identity or routing: the canonical
        // forms of an id'd request and its id-less twin are identical.
        let with = parse_line(r#"{"op":"chain","d":8,"id":"x"}"#).unwrap();
        let without = parse_line(r#"{"op":"chain","d":8}"#).unwrap();
        assert_eq!(with.canonical_line(), without.canonical_line());
        assert_eq!(with.canonical_key(), without.canonical_key());
    }

    #[test]
    fn attach_id_prefixes_without_touching_the_body() {
        let body = ok_line(obj(vec![("x", num(1.0))]), false);
        let tagged = attach_id(&body, &Json::Str("req-1".into()));
        assert!(tagged.starts_with(r#"{"id":"req-1","#), "got {tagged}");
        assert_eq!(&tagged[r#"{"id":"req-1","#.len()..], &body[1..]);
        let doc = json::parse(&tagged).unwrap();
        assert_eq!(doc.get("id").unwrap().as_str(), Some("req-1"));
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
        // Numeric ids and the empty-object edge stay valid JSON too.
        let n = attach_id("{}", &Json::Num(7.0));
        assert_eq!(json::parse(&n).unwrap().get("id").unwrap().as_usize(), Some(7));
        let err = attach_id(&err_line("nope", None), &Json::Num(3.0));
        let doc = json::parse(&err).unwrap();
        assert_eq!(doc.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false));
    }
}
