//! Offline placeholder for the `xla` crate (xla-rs).
//!
//! This crate exists so `goomrs`'s optional `xla` dependency always resolves
//! without network access or native XLA libraries. It mirrors the slice of
//! the xla-rs API that `goomrs::runtime::engine` uses:
//!
//! * [`Literal`] is a real host-side tensor (f32/i32 + dims), so literal
//!   construction and round-trips work.
//! * [`PjRtClient::cpu`], [`HloModuleProto::from_text_file`], and everything
//!   downstream of them return [`Error`] — there is no PJRT here.
//!
//! To execute AOT artifacts for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at an xla-rs checkout and rebuild with `--features xla`.

use std::fmt;

/// Error type mirroring xla-rs's: displayable and convertible via `?` into
/// `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_pjrt() -> Error {
    Error(
        "native XLA/PJRT is not linked (this is the in-repo xla-stub crate); \
         replace the `xla` path dependency with a real xla-rs checkout"
            .to_string(),
    )
}

// ------------------------------------------------------------- literals --

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side tensor: element buffer + dims. Functional (unlike the PJRT
/// types below) so conversion helpers and their tests work without XLA.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types the stub `Literal` can carry.
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![x]) }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".into()))
    }

    /// Tuple decomposition exists only on PJRT results, which the stub can
    /// never produce.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(no_pjrt())
    }
}

// ----------------------------------------------------------- PJRT stubs --

/// Unconstructable PJRT client: [`PjRtClient::cpu`] always errors.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(no_pjrt())
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(no_pjrt())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(no_pjrt())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(no_pjrt())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(no_pjrt())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x").is_err());
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }
}
