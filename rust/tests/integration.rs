//! Integration tests across the three-layer stack: AOT artifacts executed
//! through the PJRT runtime must agree with the native Rust implementations,
//! and the coordinator must drive full experiments end-to-end.
//!
//! The AOT/PJRT tests live in the `xla_integration` module and compile only
//! with the `xla` cargo feature (the default build carries a stub engine).
//! They additionally skip (pass vacuously) when `make artifacts` has not
//! run — the Makefile's `test` target builds artifacts first, so CI order
//! always exercises them.

use goomrs::coordinator::{find, Config, RunContext};

#[test]
fn chain_experiment_via_registry_end_to_end() {
    let exp = find("chain").unwrap();
    let mut cfg = Config::with_defaults(&exp.defaults());
    cfg.set("dims", "8", "cli");
    cfg.set("runs", "2", "cli");
    cfg.set("max_steps", "400", "cli");
    cfg.set("hlo", "false", "cli");
    let mut ctx = RunContext::ephemeral("itest-chain").unwrap();
    exp.run(&cfg, &mut ctx).unwrap();
    let csv = std::fs::read_to_string(ctx.run_dir.join("fig1_chain.csv")).unwrap();
    assert!(csv.lines().count() >= 5, "csv rows: {csv}");
    std::fs::remove_dir_all(&ctx.run_dir).ok();
}

#[test]
fn lyapunov_experiment_via_registry_smoke() {
    let exp = find("lyapunov").unwrap();
    let mut cfg = Config::with_defaults(&exp.defaults());
    cfg.set("steps", "1500", "cli");
    cfg.set("burn", "500", "cli");
    cfg.set("systems", "lorenz,henon", "cli");
    let mut ctx = RunContext::ephemeral("itest-lyap").unwrap();
    exp.run(&cfg, &mut ctx).unwrap();
    assert!(ctx.run_dir.join("fig3_accuracy.csv").exists());
    std::fs::remove_dir_all(&ctx.run_dir).ok();
}

#[cfg(not(feature = "xla"))]
#[test]
fn default_build_reports_missing_xla_clearly() {
    // The no-XLA stub must fail loudly at construction, not deep inside an
    // experiment, so `Engine::from_default_artifacts().ok()` probes degrade
    // to "no engine" and `repro run chain --hlo=true` still works.
    let err = goomrs::runtime::Engine::from_default_artifacts().unwrap_err();
    assert!(format!("{err:#}").contains("without XLA"));
}

#[cfg(feature = "xla")]
mod xla_integration {
    use goomrs::chain::{run_chain, Method};
    use goomrs::dynsys;
    use goomrs::goom::GoomMat;
    use goomrs::lyapunov;
    use goomrs::rnn::{CopyMemoryTask, Trainer};
    use goomrs::runtime::{
        default_artifacts_dir, goommat_stack_to_literals, lit_scalar_f32, Engine,
    };

    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping integration test");
            return None;
        }
        Some(Engine::new(dir).expect("engine"))
    }

    #[test]
    fn hlo_chain_growth_matches_native_chain() {
        let Some(engine) = engine() else { return };
        let native = run_chain(Method::GoomC64, 16, 1024, 99, None).unwrap();
        let hlo = run_chain(Method::GoomHlo, 16, 1024, 99, Some(&engine)).unwrap();
        assert!(!native.failed && !hlo.failed);
        assert_eq!(hlo.steps_completed, 1024);
        // Same growth law (different RNG draw sequence per block layout, so
        // compare rates, not values): logmag/step within 15%.
        let native_rate = native.final_max_logmag / 1024.0;
        let hlo_rate = hlo.final_max_logmag / 1024.0;
        assert!(
            (native_rate - hlo_rate).abs() < 0.15 * native_rate,
            "native {native_rate} vs hlo {hlo_rate}"
        );
    }

    #[test]
    fn lle_artifact_matches_sequential_on_lorenz_window() {
        let Some(engine) = engine() else { return };
        let sys = dynsys::by_name("lorenz").unwrap();
        let x0 = dynsys::burn_in(sys.as_ref(), 2000);
        let (jacs, _) = dynsys::jacobian_chain(sys.as_ref(), &x0, 512);
        let hlo =
            goomrs::coordinator::registry::run_lle_artifact(&engine, &jacs, sys.dt())
                .unwrap();
        let seq = lyapunov::lle_sequential(&jacs, sys.dt());
        // f32 artifact vs f64 native on a short window: loose but meaningful.
        assert!((hlo - seq).abs() < 0.05, "hlo {hlo} vs seq {seq}");
    }

    #[test]
    fn spectrum_artifact_tracks_native_parallel_on_lorenz() {
        let Some(engine) = engine() else { return };
        let sys = dynsys::by_name("lorenz").unwrap();
        let x0 = dynsys::burn_in(sys.as_ref(), 2000);
        let (jacs, _) = dynsys::jacobian_chain(sys.as_ref(), &x0, 256);
        let stack: Vec<GoomMat<f32>> =
            jacs.iter().map(GoomMat::<f32>::from_mat).collect();
        let (jl, js) = goommat_stack_to_literals(&stack).unwrap();
        let out = engine
            .run("spectrum_d3_T256", &[jl, js, lit_scalar_f32(sys.dt() as f32)])
            .unwrap();
        let lam = out[0].to_vec::<f32>().unwrap();
        // A 256-step window (2.56 Lorenz time units) is short: estimates carry
        // transient bias of a few units, so check coarse structure only — the
        // sum should sit near the trace (-13.67), λ3 must be strongly
        // negative, and the spread must reflect the dissipative split.
        assert_eq!(lam.len(), 3);
        let sum: f32 = lam.iter().sum();
        assert!((-20.0..-9.0).contains(&sum), "Σλ = {sum} (trace ≈ -13.67)");
        let mut sorted = lam.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(sorted[0] > -1.0, "λ1 near/above zero for Lorenz: {lam:?}");
        assert!(sorted[2] < -8.0, "λ3 strongly negative: {lam:?}");
    }

    #[test]
    fn trainer_forward_consistent_with_train_loss() {
        let Some(engine) = engine() else { return };
        let mut trainer = Trainer::new(&engine, "copy").unwrap();
        let spec = trainer.spec.clone();
        let mut task = CopyMemoryTask::new(spec.vocab, spec.seq_len, spec.batch, 5);
        let batch = task.next_batch();
        // Cross-check: loss from train_step ≈ NLL computed from forward logits
        // (same params before the step applies its update — so compare the
        // FIRST step's loss against a fresh trainer's forward).
        let fresh = Trainer::new(&engine, "copy").unwrap();
        let logits = fresh.forward(&batch.tokens).unwrap();
        let (b, t, v) = (spec.batch, spec.seq_len, spec.vocab);
        let mut nll = 0.0f64;
        for row in 0..b {
            for i in 0..t {
                let off = (row * t + i) * v;
                let row_logits = &logits[off..off + v];
                let m = row_logits.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let z: f32 = row_logits.iter().map(|&x| (x - m).exp()).sum();
                let target = batch.targets[row * t + i] as usize;
                nll -= (row_logits[target] - m - z.ln()) as f64;
            }
        }
        nll /= (b * t) as f64;
        let loss = trainer.train_step(&batch.tokens, &batch.targets).unwrap() as f64;
        assert!(
            (loss - nll).abs() < 1e-3,
            "train loss {loss} vs forward NLL {nll}"
        );
    }

    #[test]
    fn failure_injection_engine_rejects_malformed_artifacts() {
        // A corrupt HLO file must produce a clean error, not UB or a panic.
        let dir = std::env::temp_dir().join("goomrs_itest_badartifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"bad","path":"bad.hlo.txt","inputs":[],"outputs":[]}]}"#,
        )
        .unwrap();
        std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO").unwrap();
        let engine = Engine::new(&dir).unwrap();
        let err = match engine.run("bad", &[]) {
            Ok(_) => panic!("malformed artifact must not execute"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("bad"), "error should name the artifact: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
