//! End-to-end tests for the GBF1 binary framing against live daemons:
//! negotiation by first bytes on a shared TCP connection, decoded-result
//! equality with the JSON protocol, the shared canonical cache key across
//! encodings, verbatim frame relay through the router (including
//! failover byte-identity), oversized-frame resync, and corrupt-magic
//! fallback to line framing.

use goomrs::server::{protocol, Router, RouterConfig, ServeConfig, Server};
use goomrs::util::json;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

fn start_server() -> Server {
    Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 8,
        cache_capacity: 64,
        max_request_bytes: 8 * 1024,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .expect("server start")
}

/// A client that can speak both framings on ONE connection — the
/// per-message negotiation is part of what these tests pin down.
struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: BufWriter::new(stream),
        }
    }

    /// Encode the JSON request text as a GBF1 frame and send it.
    fn send_frame(&mut self, line: &str) {
        let doc = json::parse(line).expect("request JSON");
        let req = protocol::Request::parse(&doc).expect("request parses");
        let id = protocol::parse_id(&doc).expect("valid id");
        let frame = protocol::encode_request_frame(&req, id.as_ref());
        self.writer.write_all(&frame).unwrap();
        self.writer.flush().unwrap();
    }

    /// Read one response frame; returns its raw payload bytes.
    fn read_frame_raw(&mut self) -> Vec<u8> {
        let mut header = [0u8; protocol::FRAME_HEADER];
        self.reader.read_exact(&mut header).expect("frame header");
        assert_eq!(header[..4], protocol::FRAME_MAGIC, "response must be framed");
        let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
        let mut payload = vec![0u8; len];
        self.reader.read_exact(&mut payload).expect("frame payload");
        payload
    }

    fn read_frame(&mut self) -> Json {
        let payload = self.read_frame_raw();
        protocol::decode_response_frame(&payload).expect("decodable response frame")
    }

    fn roundtrip_bin(&mut self, line: &str) -> Json {
        self.send_frame(line);
        self.read_frame()
    }

    fn roundtrip_json(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "server closed unexpectedly");
        json::parse(resp.trim()).expect("valid JSON response")
    }
}

#[test]
fn binary_info_and_metrics_round_trip_with_id_echo() {
    let server = start_server();
    let mut client = Client::connect(server.addr());
    let info = client.roundtrip_bin(r#"{"op":"info","id":"bin-1"}"#);
    assert_eq!(info.get("ok").unwrap().as_bool(), Some(true), "{info:?}");
    assert_eq!(info.get("id").unwrap().as_str(), Some("bin-1"), "{info:?}");
    let result = info.get("result").unwrap();
    assert_eq!(result.get("service").unwrap().as_str(), Some("goomd"));
    let metrics = client.roundtrip_bin(r#"{"op":"metrics"}"#);
    assert_eq!(metrics.get("ok").unwrap().as_bool(), Some(true));
    let counters = metrics.get("result").unwrap().get("counters").unwrap();
    assert!(counters.get("requests_total").unwrap().as_usize().unwrap() >= 1);
    server.stop();
}

#[test]
fn binary_chain_and_scan_decode_identical_to_json() {
    let server = start_server();
    let mut client = Client::connect(server.addr());
    // Chain: compute cold over binary, repeat over JSON on the SAME
    // connection — both decode to the identical result document.
    let chain = protocol::encode_chain_request("goomc64", 6, 90, 777_001);
    let bin = client.roundtrip_bin(&chain);
    assert_eq!(bin.get("ok").unwrap().as_bool(), Some(true), "{bin:?}");
    assert_eq!(bin.get("cached").unwrap().as_bool(), Some(false));
    let js = client.roundtrip_json(&chain);
    assert_eq!(js.get("cached").unwrap().as_bool(), Some(true), "{js:?}");
    assert_eq!(bin.get("result").unwrap(), js.get("result").unwrap());
    // Scan: the binary request ships its matrices in the gbin tensor
    // container and the binary response returns the scan result through
    // it — the decoded document must still equal the JSON twin exactly.
    let mut rng = goomrs::rng::rng_from_seed(4321);
    let mats: Vec<goomrs::goom::GoomMat<f64>> =
        (0..4).map(|_| goomrs::goom::GoomMat::randn(3, 3, &mut rng)).collect();
    let scan = protocol::encode_scan_request(&mats, 4);
    let bin = client.roundtrip_bin(&scan);
    assert_eq!(bin.get("ok").unwrap().as_bool(), Some(true), "{bin:?}");
    let js = client.roundtrip_json(&scan);
    assert_eq!(js.get("cached").unwrap().as_bool(), Some(true), "{js:?}");
    assert_eq!(bin.get("result").unwrap(), js.get("result").unwrap());
    assert_eq!(bin.get("result").unwrap().get("len").unwrap().as_usize(), Some(4));
    server.stop();
}

#[test]
fn json_and_binary_twins_share_one_cache_entry() {
    let server = start_server();
    let mut client = Client::connect(server.addr());
    // JSON warms; the binary twin must hit — same canonical key.
    let req = protocol::encode_chain_request("goomc64", 6, 70, 88_001);
    let warm = client.roundtrip_json(&req);
    assert_eq!(warm.get("cached").unwrap().as_bool(), Some(false));
    let hit = client.roundtrip_bin(&req);
    assert_eq!(hit.get("cached").unwrap().as_bool(), Some(true), "{hit:?}");
    assert_eq!(warm.get("result").unwrap(), hit.get("result").unwrap());
    // And the other way round, from a different connection.
    let req = protocol::encode_chain_request("goomc64", 6, 70, 88_002);
    let warm = client.roundtrip_bin(&req);
    assert_eq!(warm.get("cached").unwrap().as_bool(), Some(false));
    let mut other = Client::connect(server.addr());
    let hit = other.roundtrip_json(&req);
    assert_eq!(hit.get("cached").unwrap().as_bool(), Some(true), "{hit:?}");
    assert_eq!(warm.get("result").unwrap(), hit.get("result").unwrap());
    assert!(server.counter("cache_hits") >= 2, "{}", server.metrics_summary());
    server.stop();
}

#[test]
fn binary_frames_relay_through_the_router_and_failover_is_byte_identical() {
    let live = start_server();
    // A backend that dies with requests in flight (same shape as the JSON
    // failover e2e): accepts, reads one chunk, then drops connection and
    // listener so the retry ladder exhausts on this backend.
    let dying = TcpListener::bind("127.0.0.1:0").unwrap();
    let dying_addr = dying.local_addr().unwrap().to_string();
    let killer = std::thread::spawn(move || {
        if let Ok((mut s, _)) = dying.accept() {
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink);
        }
    });
    let router = Router::start(RouterConfig {
        port: 0,
        backends: vec![live.addr().to_string(), dying_addr],
        ..RouterConfig::default()
    })
    .expect("router start");
    // Pipeline 12 distinct binary requests in one burst; with two
    // backends the odds that none ranks the dying one first are 2^-12.
    let lines: Vec<String> = (0..12u64)
        .map(|i| protocol::encode_chain_request("goomc64", 5, 30 + i as usize, 6300 + i))
        .collect();
    let mut client = Client::connect(router.addr());
    for line in &lines {
        let doc = json::parse(line).unwrap();
        let req = protocol::Request::parse(&doc).unwrap();
        let frame = protocol::encode_request_frame(&req, None);
        client.writer.write_all(&frame).unwrap();
    }
    client.writer.flush().unwrap();
    let payloads: Vec<Vec<u8>> = (0..lines.len()).map(|_| client.read_frame_raw()).collect();
    killer.join().unwrap();
    // Byte-identity through the relay: the router forwards shard frames
    // verbatim, so each payload equals what a fresh shard answers for the
    // same frame (seeded chains are deterministic), and responses came
    // back in request order.
    let fresh = start_server();
    let mut check = Client::connect(fresh.addr());
    for (req, got) in lines.iter().zip(&payloads) {
        check.send_frame(req);
        let want = check.read_frame_raw();
        assert_eq!(got, &want, "relayed frame diverged for {req}");
        let doc = protocol::decode_response_frame(got).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
    }
    for (line, payload) in lines.iter().zip(&payloads) {
        let want = json::parse(line).unwrap().get("steps").unwrap().as_usize().unwrap();
        let doc = protocol::decode_response_frame(payload).unwrap();
        let steps = doc.get("result").unwrap().get("steps_completed").unwrap();
        assert_eq!(steps.as_usize(), Some(want), "response out of request order");
    }
    assert_eq!(router.counter(&format!("routed[{}]", live.addr())), 12);
    assert!(router.counter("route_failovers") >= 1, "no failover exercised");
    assert_eq!(router.counter("route_errors"), 0);
    router.stop();
    live.stop();
    fresh.stop();
}

#[test]
fn oversized_frame_is_rejected_at_the_header_and_the_session_resyncs() {
    let server = start_server();
    let mut client = Client::connect(server.addr());
    // 8 KiB limit: declare a 16 KiB payload. The rejection fires when the
    // header arrives; the declared payload is skipped exactly.
    let len = 16 * 1024u32;
    let mut frame = protocol::FRAME_MAGIC.to_vec();
    frame.extend_from_slice(&len.to_le_bytes());
    frame.extend_from_slice(&vec![0xAB; len as usize]);
    client.writer.write_all(&frame).unwrap();
    client.writer.flush().unwrap();
    let err = client.read_frame();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false), "{err:?}");
    let msg = err.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("exceeds"), "unexpected error: {msg}");
    assert!(server.counter("oversized_rejects") >= 1);
    // Exact resync: the SAME connection keeps serving both framings.
    let ok = client.roundtrip_bin(r#"{"op":"info"}"#);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
    let ok = client.roundtrip_json(r#"{"op":"info"}"#);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn corrupt_magic_falls_back_to_line_framing_and_the_session_survives() {
    let server = start_server();
    let mut client = Client::connect(server.addr());
    // A message that diverges from the magic after 2 bytes is a line by
    // the negotiation rule — this one is not JSON either, so it earns a
    // newline-framed error, not a hang or a torn frame.
    client.writer.write_all(b"GBXX not a frame\n").unwrap();
    client.writer.flush().unwrap();
    let mut resp = String::new();
    client.reader.read_line(&mut resp).unwrap();
    let doc = json::parse(resp.trim()).expect("line-framed error");
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false), "{doc:?}");
    // A well-framed message whose payload is garbage gets a BINARY error
    // in kind, and the framing layer stays in sync.
    let mut frame = protocol::FRAME_MAGIC.to_vec();
    frame.extend_from_slice(&5u32.to_le_bytes());
    frame.extend_from_slice(&[0xFF; 5]);
    client.writer.write_all(&frame).unwrap();
    client.writer.flush().unwrap();
    let err = client.read_frame();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false), "{err:?}");
    // The same connection still answers real work in both framings.
    let ok = client.roundtrip_bin(&protocol::encode_chain_request("goomc64", 4, 16, 3));
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true), "{ok:?}");
    let ok = client.roundtrip_json(r#"{"op":"info"}"#);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}
