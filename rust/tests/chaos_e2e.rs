//! Chaos end-to-end: a live `goomd` under a deterministic fault plan
//! (connection drops, stalls, short writes at every reactor IO seam) must
//! shed or delay requests but never corrupt one — every response the
//! chaos loadgen client actually receives is verified byte-for-byte
//! against a local recompute of the same request.
//!
//! This lives in its own integration-test binary because the fault plan is
//! process-global (`faults::install_str` behind one atomic gate): sharing
//! a binary with fault-free e2e tests would race the gate across the test
//! harness's worker threads.

use goomrs::coordinator::Metrics;
use goomrs::server::{self, LoadgenConfig, ServeConfig, Server};

/// One retried metrics probe: individual attempts may themselves be
/// killed by the fault plan (that is the point), so try a few times.
fn metrics_line(addr: &str) -> String {
    for _ in 0..20 {
        if let Ok(line) = server::request_once(addr, "{\"op\":\"metrics\"}") {
            return line;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    panic!("metrics op never survived the fault plan");
}

#[test]
fn fault_injection_sheds_or_delays_but_never_corrupts() {
    let server = Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 4,
        cache_capacity: 64,
        max_request_bytes: 64 * 1024,
        retry_after_ms: 5,
        // Aggressive plan: drops force reconnect+replay, stalls exercise
        // deadlines, short writes exercise partial-flush resumption.
        faults: "seed=42,conn_drop=0.10,stall_ms=10@0.05,short_write=0.25".to_string(),
        ..ServeConfig::default()
    })
    .expect("server under faults");
    let addr = server.addr().to_string();

    let cfg = LoadgenConfig {
        addr: addr.clone(),
        clients: 4,
        requests: 12,
        d: 6,
        steps: 50,
        dims: Vec::new(),
        method: "goomc64".to_string(),
        shared_seed: None,
        pipeline: 1,
        threads: 0,
        chaos: true,
        binary: false,
        ..LoadgenConfig::default()
    };
    let mut metrics = Metrics::new();
    let report = server::loadgen(&cfg, &mut metrics).expect("chaos loadgen");

    // The byte-identity contract: faults may shed or delay a request, but
    // every response that IS delivered matches a fault-free recompute.
    assert_eq!(report.corrupt, 0, "fault injection corrupted a response");
    assert_eq!(report.errors, 0, "chaos client gave up on a request");
    assert_eq!(report.ok, 4 * 12, "every request eventually answered");

    // Same contract over GBF1 binary framing, against the same live fault
    // plan: short writes now cut frames mid-header and mid-payload, drops
    // force reconnect+replay of framed requests — delivered results must
    // still decode byte-identical to the local recompute.
    let bin = LoadgenConfig { binary: true, ..cfg.clone() };
    let report = server::loadgen(&bin, &mut metrics).expect("binary chaos loadgen");
    assert_eq!(report.corrupt, 0, "fault injection corrupted a binary response");
    assert_eq!(report.errors, 0, "binary chaos client gave up on a request");
    assert_eq!(report.ok, 4 * 12, "every binary request eventually answered");

    // The plan was armed and observable: the shard's metrics op exports a
    // "faults" section only when injection is enabled.
    let line = metrics_line(&addr);
    assert!(line.contains("\"faults\""), "no faults section in: {line}");
    server.stop();
}
