//! End-to-end tests for the router tier: live `goomd` shards behind a
//! rendezvous-hashing `repro route` front. Covers cache-affine routing,
//! spread of distinct keys, local introspection, failover past a dead
//! backend, and protocol error handling through the relay.

use goomrs::server::{protocol, Router, RouterConfig, Server, ServeConfig};
use goomrs::util::json::{self, Json};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};

fn start_shard() -> Server {
    Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 4,
        cache_capacity: 64,
        max_request_bytes: 64 * 1024,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .expect("shard start")
}

fn start_router(backends: Vec<String>) -> Router {
    Router::start(RouterConfig { port: 0, backends, ..RouterConfig::default() })
        .expect("router start")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: BufWriter::new(stream),
        }
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "router closed unexpectedly");
        json::parse(resp.trim()).expect("response must be valid JSON")
    }
}

#[test]
fn repeated_keys_route_to_the_owning_shard_and_hit_its_cache() {
    let a = start_shard();
    let b = start_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let req = protocol::encode_chain_request("goomc64", 6, 80, 12345);
    let first = client.roundtrip(&req);
    assert_eq!(first.get("ok").unwrap().as_bool(), Some(true), "{first:?}");
    assert_eq!(first.get("cached").unwrap().as_bool(), Some(false));
    for _ in 0..2 {
        let again = client.roundtrip(&req);
        assert_eq!(again.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(again.get("result").unwrap(), first.get("result").unwrap());
    }
    // Exactly one shard computed and served the repeats from its cache.
    let misses = (a.counter("cache_misses"), b.counter("cache_misses"));
    let hits = (a.counter("cache_hits"), b.counter("cache_hits"));
    assert!(
        (misses == (1, 0) && hits == (2, 0))
            || (misses == (0, 1) && hits == (0, 2)),
        "cache traffic split across shards: misses {misses:?}, hits {hits:?}"
    );
    // The router's per-shard counters agree: all three went one way.
    let routed_a = router.counter(&format!("routed[{}]", a.addr()));
    let routed_b = router.counter(&format!("routed[{}]", b.addr()));
    assert!(
        (routed_a, routed_b) == (3, 0) || (routed_a, routed_b) == (0, 3),
        "routed[a]={routed_a} routed[b]={routed_b}"
    );
    // A differently-spelled but canonically-identical request still lands
    // on the owning shard and hits its cache.
    let implicit = r#"{"op":"chain","d":6,"steps":80,"seed":12345}"#;
    let doc = client.roundtrip(implicit);
    assert_eq!(doc.get("cached").unwrap().as_bool(), Some(true), "{doc:?}");
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn distinct_keys_spread_across_shards() {
    let a = start_shard();
    let b = start_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    for seed in 0..24 {
        let resp = client
            .roundtrip(&protocol::encode_chain_request("goomc64", 4, 40, seed));
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    }
    let routed_a = router.counter(&format!("routed[{}]", a.addr()));
    let routed_b = router.counter(&format!("routed[{}]", b.addr()));
    assert_eq!(routed_a + routed_b, 24);
    // 24 distinct keys all landing on one shard has probability 2^-23.
    assert!(routed_a > 0 && routed_b > 0, "no spread: {routed_a} vs {routed_b}");
    // Each shard computed exactly what was routed to it.
    assert_eq!(a.counter("cache_misses"), routed_a);
    assert_eq!(b.counter("cache_misses"), routed_b);
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn router_answers_introspection_locally() {
    let a = start_shard();
    let router = start_router(vec![a.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let info = client.roundtrip(r#"{"op":"info"}"#);
    assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
    let result = info.get("result").unwrap();
    assert_eq!(result.get("service").unwrap().as_str(), Some("goomd-router"));
    assert_eq!(result.get("backends").unwrap().as_arr().unwrap().len(), 1);
    // Shards saw nothing: introspection never leaves the router.
    assert_eq!(a.counter("requests_total"), 0);
    // Metrics carry the per-shard routing counters once traffic flows.
    let _ = client.roundtrip(&protocol::encode_chain_request("goomc64", 4, 30, 7));
    let metrics = client.roundtrip(r#"{"op":"metrics"}"#);
    let counters = metrics.get("result").unwrap().get("counters").unwrap();
    let routed = counters.get(&format!("routed[{}]", a.addr())).unwrap();
    assert_eq!(routed.as_usize(), Some(1), "{metrics:?}");
    router.stop();
    a.stop();
}

#[test]
fn dead_backend_fails_over_to_the_next_ranked_shard() {
    let live = start_shard();
    // A dead address: bind an ephemeral port, then drop the listener.
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let router = start_router(vec![live.addr().to_string(), dead_addr]);
    let mut client = Client::connect(router.addr());
    for seed in 0..20 {
        let resp = client
            .roundtrip(&protocol::encode_chain_request("goomc64", 4, 30, seed));
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    }
    // Every request succeeded on the live shard; the ~half that ranked the
    // dead backend first (P[none] = 2^-20) were failovers.
    assert_eq!(
        router.counter(&format!("routed[{}]", live.addr())),
        20
    );
    assert!(router.counter("route_failovers") >= 1);
    assert_eq!(router.counter("route_errors"), 0);
    router.stop();
    live.stop();
}

#[test]
fn malformed_lines_through_the_router_get_errors_and_the_session_survives() {
    let a = start_shard();
    let router = start_router(vec![a.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let resp = client.roundtrip("this is not json");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    let resp = client.roundtrip(r#"{"op":"teleport"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    // Unknown-system errors relay back from the shard transparently.
    let resp = client.roundtrip(r#"{"op":"lle","system":"narnia"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("unknown system"));
    // The same connection still serves valid requests afterwards.
    let resp = client.roundtrip(&protocol::encode_chain_request("goomc64", 4, 16, 1));
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    router.stop();
    a.stop();
}
