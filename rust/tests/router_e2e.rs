//! End-to-end tests for the router tier: live `goomd` shards behind a
//! rendezvous-hashing `repro route` front, both tiers on the shared
//! serving reactor. Covers cache-affine routing, spread of distinct keys,
//! local introspection, failover past a dead backend, protocol error
//! handling through the relay, pipelined ordering through the reorder
//! buffers, mid-pipeline backend death, and the O(1)-thread front.

use goomrs::server::{protocol, request_once, Router, RouterConfig, Server, ServeConfig};
use goomrs::util::json::{self, Json};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

fn start_shard() -> Server {
    Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 4,
        cache_capacity: 64,
        max_request_bytes: 64 * 1024,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .expect("shard start")
}

fn start_router(backends: Vec<String>) -> Router {
    Router::start(RouterConfig { port: 0, backends, ..RouterConfig::default() })
        .expect("router start")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: BufWriter::new(stream),
        }
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "router closed unexpectedly");
        json::parse(resp.trim()).expect("response must be valid JSON")
    }
}

#[test]
fn repeated_keys_route_to_the_owning_shard_and_hit_its_cache() {
    let a = start_shard();
    let b = start_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let req = protocol::encode_chain_request("goomc64", 6, 80, 12345);
    let first = client.roundtrip(&req);
    assert_eq!(first.get("ok").unwrap().as_bool(), Some(true), "{first:?}");
    assert_eq!(first.get("cached").unwrap().as_bool(), Some(false));
    for _ in 0..2 {
        let again = client.roundtrip(&req);
        assert_eq!(again.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(again.get("result").unwrap(), first.get("result").unwrap());
    }
    // Exactly one shard computed and served the repeats from its cache.
    let misses = (a.counter("cache_misses"), b.counter("cache_misses"));
    let hits = (a.counter("cache_hits"), b.counter("cache_hits"));
    assert!(
        (misses == (1, 0) && hits == (2, 0))
            || (misses == (0, 1) && hits == (0, 2)),
        "cache traffic split across shards: misses {misses:?}, hits {hits:?}"
    );
    // The router's per-shard counters agree: all three went one way.
    let routed_a = router.counter(&format!("routed[{}]", a.addr()));
    let routed_b = router.counter(&format!("routed[{}]", b.addr()));
    assert!(
        (routed_a, routed_b) == (3, 0) || (routed_a, routed_b) == (0, 3),
        "routed[a]={routed_a} routed[b]={routed_b}"
    );
    // A differently-spelled but canonically-identical request still lands
    // on the owning shard and hits its cache.
    let implicit = r#"{"op":"chain","d":6,"steps":80,"seed":12345}"#;
    let doc = client.roundtrip(implicit);
    assert_eq!(doc.get("cached").unwrap().as_bool(), Some(true), "{doc:?}");
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn distinct_keys_spread_across_shards() {
    let a = start_shard();
    let b = start_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    for seed in 0..24 {
        let resp = client
            .roundtrip(&protocol::encode_chain_request("goomc64", 4, 40, seed));
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    }
    let routed_a = router.counter(&format!("routed[{}]", a.addr()));
    let routed_b = router.counter(&format!("routed[{}]", b.addr()));
    assert_eq!(routed_a + routed_b, 24);
    // 24 distinct keys all landing on one shard has probability 2^-23.
    assert!(routed_a > 0 && routed_b > 0, "no spread: {routed_a} vs {routed_b}");
    // Each shard computed exactly what was routed to it.
    assert_eq!(a.counter("cache_misses"), routed_a);
    assert_eq!(b.counter("cache_misses"), routed_b);
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn router_answers_introspection_locally() {
    let a = start_shard();
    let router = start_router(vec![a.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let info = client.roundtrip(r#"{"op":"info"}"#);
    assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
    let result = info.get("result").unwrap();
    assert_eq!(result.get("service").unwrap().as_str(), Some("goomd-router"));
    assert_eq!(result.get("backends").unwrap().as_arr().unwrap().len(), 1);
    // Shards saw nothing: introspection never leaves the router.
    assert_eq!(a.counter("requests_total"), 0);
    // Metrics carry the per-shard routing counters once traffic flows.
    let _ = client.roundtrip(&protocol::encode_chain_request("goomc64", 4, 30, 7));
    let metrics = client.roundtrip(r#"{"op":"metrics"}"#);
    let counters = metrics.get("result").unwrap().get("counters").unwrap();
    let routed = counters.get(&format!("routed[{}]", a.addr())).unwrap();
    assert_eq!(routed.as_usize(), Some(1), "{metrics:?}");
    router.stop();
    a.stop();
}

#[test]
fn dead_backend_fails_over_to_the_next_ranked_shard() {
    let live = start_shard();
    // A dead address: bind an ephemeral port, then drop the listener.
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let router = start_router(vec![live.addr().to_string(), dead_addr]);
    let mut client = Client::connect(router.addr());
    for seed in 0..20 {
        let resp = client
            .roundtrip(&protocol::encode_chain_request("goomc64", 4, 30, seed));
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    }
    // Every request succeeded on the live shard; the ~half that ranked the
    // dead backend first (P[none] = 2^-20) were failovers.
    assert_eq!(
        router.counter(&format!("routed[{}]", live.addr())),
        20
    );
    assert!(router.counter("route_failovers") >= 1);
    assert_eq!(router.counter("route_errors"), 0);
    router.stop();
    live.stop();
}

#[test]
fn pipelined_mixed_requests_come_back_in_request_order() {
    let a = start_shard();
    let b = start_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    let stream = TcpStream::connect(router.addr()).expect("connect");
    let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
    // One burst of 9 lines: 8 computes with distinct step counts (the
    // order witness — each response echoes steps_completed) spread across
    // both shards by their distinct seeds, plus an introspection op in the
    // middle that completes instantly but must wait its turn in the
    // reorder buffer.
    let steps: Vec<usize> = (1..=8).map(|i| 10 * i).collect();
    let mut burst = String::new();
    for (i, &s) in steps.iter().enumerate() {
        if i == 4 {
            burst.push_str("{\"op\":\"info\"}\n");
        }
        burst.push_str(&protocol::encode_chain_request("goomc64", 5, s, 9000 + i as u64));
        burst.push('\n');
    }
    writer.write_all(burst.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut chain_slot = 0usize;
    for slot in 0..9 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "missing response {slot}");
        let doc = json::parse(line.trim()).expect("valid JSON");
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
        let result = doc.get("result").unwrap();
        if slot == 4 {
            assert_eq!(result.get("service").unwrap().as_str(), Some("goomd-router"));
        } else {
            assert_eq!(
                result.get("steps_completed").unwrap().as_usize(),
                Some(steps[chain_slot]),
                "response {slot} out of request order"
            );
            chain_slot += 1;
        }
    }
    let routed_a = router.counter(&format!("routed[{}]", a.addr()));
    let routed_b = router.counter(&format!("routed[{}]", b.addr()));
    assert_eq!(routed_a + routed_b, 8);
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn backend_death_mid_pipeline_fails_over_with_byte_identical_responses() {
    let live = start_shard();
    // A backend that dies with requests in flight: it accepts the router's
    // connection, reads one chunk of relayed requests, then drops both the
    // connection and the listener (so the fresh-connection retry is
    // refused too, exhausting the one-retry ladder on this backend).
    let dying = TcpListener::bind("127.0.0.1:0").unwrap();
    let dying_addr = dying.local_addr().unwrap().to_string();
    let killer = std::thread::spawn(move || {
        if let Ok((mut s, _)) = dying.accept() {
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink);
        } // connection and listener both drop (close) here
    });
    let router = start_router(vec![live.addr().to_string(), dying_addr]);
    let stream = TcpStream::connect(router.addr()).expect("connect");
    let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
    // Pipeline 12 distinct requests in one burst; with two backends, the
    // odds that none ranks the dying backend first are 2^-12.
    let lines: Vec<String> = (0..12u64)
        .map(|i| protocol::encode_chain_request("goomc64", 5, 30 + i as usize, 4200 + i))
        .collect();
    for line in &lines {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
    }
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut responses = Vec::new();
    for i in 0..lines.len() {
        let mut resp = String::new();
        assert!(reader.read_line(&mut resp).unwrap() > 0, "missing response {i}");
        responses.push(resp.trim_end().to_string());
    }
    killer.join().unwrap();
    // Every response came back in order and ok, byte-identical to what a
    // fresh shard answers for the same canonical request line (seeded
    // chains are deterministic, so first-computation responses match to
    // the byte).
    let fresh = start_shard();
    for (req, got) in lines.iter().zip(&responses) {
        let doc = json::parse(req).unwrap();
        let canonical = protocol::Request::parse(&doc)
            .expect("valid request")
            .canonical_line()
            .expect("compute request");
        let want = request_once(&fresh.addr().to_string(), &canonical).expect("fresh shard");
        assert_eq!(got, &want, "relayed response diverged for {req}");
    }
    // The one-retry ranked failover moved every request (and its routing
    // counter) to the surviving shard.
    assert_eq!(router.counter(&format!("routed[{}]", live.addr())), 12);
    assert!(router.counter("route_failovers") >= 1, "no failover exercised");
    assert_eq!(router.counter("route_errors"), 0);
    router.stop();
    live.stop();
    fresh.stop();
}

#[test]
fn backend_pool_lets_fast_requests_overtake_a_slow_one() {
    // One shard with two workers behind a router with a 2-deep backend
    // pool: a slow compute occupies pooled connection 1 while a fast
    // request from another client relays on connection 2. With the old
    // single shared connection per shard the fast response could only
    // arrive after the slow one finished (per-connection FIFO) — the
    // cross-client head-of-line blocking the pool exists to remove.
    let shard = Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 1,
        cache_capacity: 64,
        max_request_bytes: 64 * 1024,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .expect("shard start");
    let router = Router::start(RouterConfig {
        port: 0,
        backends: vec![shard.addr().to_string()],
        backend_pool: 2,
        ..RouterConfig::default()
    })
    .expect("router start");
    let mut slow = Client::connect(router.addr());
    let mut fast = Client::connect(router.addr());
    // Launch the slow chain (hundreds of ms of kernel time) and give the
    // relay a beat to put it in flight on pooled connection 1.
    let t0 = Instant::now();
    let slow_req = protocol::encode_chain_request("goomc64", 64, 2500, 1);
    slow.writer.write_all(slow_req.as_bytes()).unwrap();
    slow.writer.write_all(b"\n").unwrap();
    slow.writer.flush().unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let resp = fast.roundtrip(&protocol::encode_chain_request("goomc64", 4, 10, 2));
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    let t_fast = t0.elapsed();
    let mut line = String::new();
    slow.reader.read_line(&mut line).unwrap();
    let t_slow = t0.elapsed();
    let doc = json::parse(line.trim()).expect("valid JSON");
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
    // The overtake must be decisive, not a photo finish at the tail of
    // the slow compute.
    assert!(
        t_fast < t_slow / 2,
        "fast response blocked behind slow one: fast {t_fast:?} vs slow {t_slow:?}"
    );
    // And it came from the pool: the router grew a second loop-managed
    // connection toward the single shard.
    let metrics = fast.roundtrip(r#"{"op":"metrics"}"#);
    let reactor = metrics.get("result").unwrap().get("reactor").unwrap();
    assert!(
        reactor.get("fds_connected").unwrap().as_usize().unwrap() >= 2,
        "{reactor:?}"
    );
    router.stop();
    shard.stop();
}

#[test]
fn pooled_sharded_front_fails_over_with_byte_identical_responses() {
    // The mid-pipeline-death byte-identity contract, now under the full
    // new topology: --reactors=2 (each pipelining client owned by its own
    // reactor, each reactor owning private backend pools) and
    // --backend-pool=4 (pooled connections toward the dying backend fail
    // individually — accepted-then-killed and connect-refused paths both
    // walk the one-retry ladder).
    let live = start_shard();
    let dying = TcpListener::bind("127.0.0.1:0").unwrap();
    let dying_addr = dying.local_addr().unwrap().to_string();
    let killer = std::thread::spawn(move || {
        if let Ok((mut s, _)) = dying.accept() {
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink);
        } // connection and listener both drop (close) here
    });
    let router = Router::start(RouterConfig {
        port: 0,
        backends: vec![live.addr().to_string(), dying_addr],
        reactors: 2,
        backend_pool: 4,
        ..RouterConfig::default()
    })
    .expect("router start");
    // Two clients, dealt round-robin to the two reactors, each pipelining
    // 8 distinct requests in one burst. With two backends the odds that
    // none of the 16 ranks the dying backend first are 2^-16.
    let streams: Vec<TcpStream> = (0..2)
        .map(|_| TcpStream::connect(router.addr()).expect("connect"))
        .collect();
    let lines: Vec<Vec<String>> = (0..2u64)
        .map(|c| {
            (0..8u64)
                .map(|i| {
                    protocol::encode_chain_request(
                        "goomc64",
                        5,
                        30 + i as usize,
                        5000 + c * 100 + i,
                    )
                })
                .collect()
        })
        .collect();
    for (stream, client_lines) in streams.iter().zip(&lines) {
        let mut writer = stream;
        let mut burst = String::new();
        for line in client_lines {
            burst.push_str(line);
            burst.push('\n');
        }
        writer.write_all(burst.as_bytes()).unwrap();
    }
    let mut responses: Vec<Vec<String>> = Vec::new();
    for stream in &streams {
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut client_responses = Vec::new();
        for i in 0..8 {
            let mut resp = String::new();
            assert!(reader.read_line(&mut resp).unwrap() > 0, "missing response {i}");
            client_responses.push(resp.trim_end().to_string());
        }
        responses.push(client_responses);
    }
    killer.join().unwrap();
    // Every response, on both clients, in request order, byte-identical
    // to a fresh shard's answer for the same canonical line.
    let fresh = start_shard();
    for (client_lines, client_responses) in lines.iter().zip(&responses) {
        for (req, got) in client_lines.iter().zip(client_responses) {
            let doc = json::parse(req).unwrap();
            let canonical = protocol::Request::parse(&doc)
                .expect("valid request")
                .canonical_line()
                .expect("compute request");
            let want =
                request_once(&fresh.addr().to_string(), &canonical).expect("fresh shard");
            assert_eq!(got, &want, "relayed response diverged for {req}");
        }
    }
    assert_eq!(router.counter(&format!("routed[{}]", live.addr())), 16);
    assert!(router.counter("route_failovers") >= 1, "no failover exercised");
    assert_eq!(router.counter("route_errors"), 0);
    // Both reactors actually served a client (the acceptor dealt them out).
    let mut client = Client::connect(router.addr());
    let metrics = client.roundtrip(r#"{"op":"metrics"}"#);
    let reactor = metrics.get("result").unwrap().get("reactor").unwrap();
    assert_eq!(reactor.get("reactors").unwrap().as_usize(), Some(2));
    let per = reactor.get("per_reactor").unwrap().as_arr().unwrap();
    assert_eq!(per.len(), 2);
    for block in per {
        assert!(block.get("fds_accepted").unwrap().as_usize().unwrap() >= 1, "{per:?}");
    }
    router.stop();
    live.stop();
    fresh.stop();
}

#[cfg(target_os = "linux")]
fn proc_thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|n| n.parse().ok())
        })
        .expect("parsing /proc/self/status")
}

#[test]
fn many_pipelined_clients_cost_the_router_no_extra_threads() {
    // Deep queues: 160 requests land almost simultaneously through the
    // pipelined relay, and this test is about threads, not load shedding.
    let deep_shard = || {
        Server::start(ServeConfig {
            port: 0,
            workers: 2,
            queue_depth: 256,
            batch_max: 8,
            cache_capacity: 64,
            max_request_bytes: 64 * 1024,
            retry_after_ms: 5,
            ..ServeConfig::default()
        })
        .expect("shard start")
    };
    let a = deep_shard();
    let b = deep_shard();
    let router = start_router(vec![a.addr().to_string(), b.addr().to_string()]);
    #[cfg(target_os = "linux")]
    let threads_before = proc_thread_count();
    // 40 live client connections, each pipelining 4 requests, relayed
    // across 2 shards — the pre-reactor router would have spawned a relay
    // thread per client.
    let conns: Vec<TcpStream> =
        (0..40).map(|_| TcpStream::connect(router.addr()).expect("connect")).collect();
    for (c, stream) in conns.iter().enumerate() {
        let mut burst = String::new();
        for r in 0..4u64 {
            burst.push_str(&protocol::encode_chain_request(
                "goomc64",
                4,
                20,
                (c as u64) * 1000 + r,
            ));
            burst.push('\n');
        }
        let mut writer = stream;
        writer.write_all(burst.as_bytes()).unwrap();
    }
    for stream in &conns {
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        for _ in 0..4 {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "missing response");
            let doc = json::parse(line.trim()).expect("valid JSON");
            assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
        }
    }
    #[cfg(target_os = "linux")]
    {
        // The router added exactly one reactor thread at start (already
        // counted in the baseline); serving 40 pipelined clients must not
        // add any. Other tests in this binary run concurrently and start
        // their own shards/routers (a few bounded threads each), so allow
        // slack — but nothing near one thread per client. The strict
        // process-level assertion (router == 2 threads total) lives in the
        // route-smoke CI job, where the router runs alone in its process.
        let threads_after = proc_thread_count();
        assert!(
            threads_after < threads_before + 25,
            "router connections must not cost threads: {threads_before} -> {threads_after}"
        );
    }
    let routed = router.counter(&format!("routed[{}]", a.addr()))
        + router.counter(&format!("routed[{}]", b.addr()));
    assert_eq!(routed, 160);
    // The reactor counters the router exports under "reactor" moved.
    let mut client = Client::connect(router.addr());
    let metrics = client.roundtrip(r#"{"op":"metrics"}"#);
    let reactor = metrics.get("result").unwrap().get("reactor").unwrap();
    assert!(reactor.get("loop_iterations").unwrap().as_usize().unwrap() > 0);
    assert!(reactor.get("fds_accepted").unwrap().as_usize().unwrap() >= 41);
    assert!(reactor.get("fds_connected").unwrap().as_usize().unwrap() >= 1);
    drop(conns);
    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn client_id_survives_the_relay_and_traces_stitch_across_tiers() {
    // Both tiers opt into tracing (enable-only: neither start can shut the
    // gate another test opened). Explicit-id requests are always traced
    // while the gate is open, so this test doesn't depend on sampling luck.
    let traced_shard = || {
        Server::start(ServeConfig {
            port: 0,
            workers: 2,
            queue_depth: 16,
            batch_max: 4,
            cache_capacity: 64,
            max_request_bytes: 64 * 1024,
            retry_after_ms: 5,
            trace_sample: 1,
            ..ServeConfig::default()
        })
        .expect("shard start")
    };
    let a = traced_shard();
    let b = traced_shard();
    let router = Router::start(RouterConfig {
        port: 0,
        backends: vec![a.addr().to_string(), b.addr().to_string()],
        trace_sample: 1,
        ..RouterConfig::default()
    })
    .expect("router start");

    // Raw-line client: the byte-exact echo is the point, so don't parse
    // before asserting on the bytes.
    let stream = TcpStream::connect(router.addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream);
    let mut raw_roundtrip = move |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "router closed unexpectedly");
        resp.trim_end().to_string()
    };

    // A string id on a compute request: relayed router → shard → response,
    // echoed byte-exactly as the FIRST response key.
    let resp = raw_roundtrip(
        r#"{"op":"chain","method":"goomc64","d":4,"steps":30,"seed":4242,"id":"trace-probe-1"}"#,
    );
    assert!(
        resp.starts_with(r#"{"id":"trace-probe-1","#),
        "id must lead the response bytes: {resp}"
    );
    let doc = json::parse(&resp).expect("valid JSON");
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
    // The chain result carries the GOOM dynamic-range telemetry.
    let result = doc.get("result").unwrap();
    assert!(
        result.get("dynamic_range_decades").unwrap().as_f64().unwrap() > 0.0,
        "{result:?}"
    );

    // Integer ids round-trip as numbers, not strings.
    let resp = raw_roundtrip(r#"{"op":"chain","d":4,"steps":30,"seed":4243,"id":77}"#);
    assert!(resp.starts_with(r#"{"id":77,"#), "integer id echo: {resp}");

    // Router-local introspection echoes the id too (never reaches a shard).
    let resp = raw_roundtrip(r#"{"op":"info","id":"meta-1"}"#);
    assert!(resp.starts_with(r#"{"id":"meta-1","#), "info id echo: {resp}");

    // The trace op returns recent spans; the relayed request's id shows up
    // under BOTH tier labels (the relayed canonical line carries the id, so
    // the shard's spans join the router's under one request id — exactly
    // what `repro trace` stitches into one Chrome timeline).
    let resp = raw_roundtrip(r#"{"op":"trace","limit":100000}"#);
    let doc = json::parse(&resp).expect("valid JSON");
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{doc:?}");
    let spans = doc
        .get("result")
        .unwrap()
        .get("spans")
        .unwrap()
        .as_arr()
        .expect("spans array");
    let tiers_for = |id: &str| -> Vec<&str> {
        spans
            .iter()
            .filter(|s| s.get("id").and_then(Json::as_str) == Some(id))
            .map(|s| s.get("tier").unwrap().as_str().unwrap())
            .collect()
    };
    let probe_tiers = tiers_for("trace-probe-1");
    assert!(
        probe_tiers.contains(&"router") && probe_tiers.contains(&"server"),
        "spans must stitch across tiers, saw {probe_tiers:?}"
    );
    // The shard side attributed real stages to the request, not just decode.
    let probe_stages: Vec<&str> = spans
        .iter()
        .filter(|s| {
            s.get("id").and_then(Json::as_str) == Some("trace-probe-1")
                && s.get("tier").and_then(Json::as_str) == Some("server")
        })
        .map(|s| s.get("stage").unwrap().as_str().unwrap())
        .collect();
    assert!(probe_stages.contains(&"kernel"), "shard stages: {probe_stages:?}");
    assert!(probe_stages.contains(&"serialize"), "shard stages: {probe_stages:?}");

    router.stop();
    a.stop();
    b.stop();
}

#[test]
fn malformed_lines_through_the_router_get_errors_and_the_session_survives() {
    let a = start_shard();
    let router = start_router(vec![a.addr().to_string()]);
    let mut client = Client::connect(router.addr());
    let resp = client.roundtrip("this is not json");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    let resp = client.roundtrip(r#"{"op":"teleport"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    // Unknown-system errors relay back from the shard transparently.
    let resp = client.roundtrip(r#"{"op":"lle","system":"narnia"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("unknown system"));
    // The same connection still serves valid requests afterwards.
    let resp = client.roundtrip(&protocol::encode_chain_request("goomc64", 4, 16, 1));
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    router.stop();
    a.stop();
}
