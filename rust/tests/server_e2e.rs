//! End-to-end tests against a live `goomd` daemon over real TCP: protocol
//! round-trips, result correctness vs the in-process kernels, cache
//! behaviour, oversized-request rejection, in-flight dedup, and batched
//! scans.

use goomrs::goom::{lmme, scan_par_chunked, GoomMat};
use goomrs::rng::rng_from_seed;
use goomrs::server::{protocol, Server, ServeConfig};
use goomrs::util::json::{self, Json};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn start_server() -> Server {
    Server::start(ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 16,
        batch_max: 8,
        cache_capacity: 64,
        max_request_bytes: 8 * 1024,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .expect("server start")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(server: &Server) -> Client {
        let stream = TcpStream::connect(server.addr()).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: BufWriter::new(stream),
        }
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "server closed unexpectedly");
        json::parse(resp.trim()).expect("response must be valid JSON")
    }
}

#[test]
fn scan_request_matches_local_lmme_chain() {
    let server = start_server();
    let mut client = Client::connect(&server);
    // Build 5 random 3x3 GOOM transition matrices locally...
    let mut rng = rng_from_seed(1234);
    let mats: Vec<GoomMat<f64>> =
        (0..5).map(|_| GoomMat::randn(3, 3, &mut rng)).collect();
    // ...run the identical scan in-process (same chunks/threads as the
    // server's executor, so results match bit-for-bit up to the JSON
    // round-trip, which Rust's shortest-representation floats survive)...
    let combine = |earlier: &GoomMat<f64>, later: &GoomMat<f64>| lmme(later, earlier);
    let scanned = scan_par_chunked(&mats, combine, 4, 1);
    let local = scanned.last().unwrap();
    // ...and sanity-check that against the plain sequential product.
    let mut seq = mats[0].clone();
    for a in &mats[1..] {
        seq = lmme(a, &seq);
    }
    for i in 0..9 {
        assert!(
            (local.logmag[i] - seq.logmag[i]).abs()
                <= 1e-9 * seq.logmag[i].abs().max(1.0),
            "scan schedule disagrees with sequential at [{i}]"
        );
    }
    // Now ask the daemon for the same scan.
    let resp = client.roundtrip(&protocol::encode_scan_request(&mats, 4));
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    let result = resp.get("result").unwrap();
    assert_eq!(result.get("len").unwrap().as_usize(), Some(5));
    let logmag = result.get("logmag").unwrap().as_arr().unwrap();
    let sign = result.get("sign").unwrap().as_arr().unwrap();
    assert_eq!(logmag.len(), 9);
    for i in 0..9 {
        let got = logmag[i].as_f64().unwrap_or(f64::NEG_INFINITY);
        assert_eq!(got, local.logmag[i], "logmag[{i}]");
        assert_eq!(sign[i].as_f64().unwrap(), local.sign[i], "sign[{i}]");
    }
    server.stop();
}

#[test]
fn lle_request_returns_a_plausible_lorenz_exponent() {
    let server = start_server();
    let mut client = Client::connect(&server);
    let resp = client
        .roundtrip(r#"{"op":"lle","system":"lorenz","steps":3000,"burn":1000,"chunks":32}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    let lle = resp.get("result").unwrap().get("lle").unwrap().as_f64().unwrap();
    // Lorenz λ1 ≈ 0.9; a short window carries bias, so bound loosely.
    assert!((0.5..1.3).contains(&lle), "λ1 = {lle}");
    // Unknown systems are a clean protocol error, not a hang or crash.
    let resp = client.roundtrip(r#"{"op":"lle","system":"narnia"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("unknown system"));
    server.stop();
}

#[test]
fn cache_hit_on_repeated_seeded_request_shows_in_metrics() {
    let server = start_server();
    let mut a = Client::connect(&server);
    let req = protocol::encode_chain_request("goomc64", 6, 64, 2024);
    let first = a.roundtrip(&req);
    assert_eq!(first.get("cached").unwrap().as_bool(), Some(false));
    // A *different* connection repeating the request must hit the cache.
    let mut b = Client::connect(&server);
    let second = b.roundtrip(&req);
    assert_eq!(second.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(first.get("result").unwrap(), second.get("result").unwrap());
    // And the daemon's own metrics op must report the hit.
    let metrics = b.roundtrip(r#"{"op":"metrics"}"#);
    let counters = metrics.get("result").unwrap().get("counters").unwrap();
    assert!(counters.get("cache_hits").unwrap().as_usize().unwrap() >= 1);
    assert!(counters.get("cache_misses").unwrap().as_usize().unwrap() >= 1);
    assert!(server.counter("cache_hits") >= 1);
    server.stop();
}

#[test]
fn oversized_request_is_rejected_cleanly() {
    let server = start_server();
    let mut client = Client::connect(&server);
    // 8 KiB limit: build a ~16 KiB single-line request.
    let big = format!(
        r#"{{"op":"chain","steps":10,"junk":"{}"}}"#,
        "x".repeat(16 * 1024)
    );
    let resp = client.roundtrip(&big);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    let msg = resp.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("exceeds"), "unexpected error: {msg}");
    assert!(server.counter("oversized_rejects") >= 1);
    // The session discards through the newline and resyncs: the SAME
    // connection keeps serving valid requests afterwards.
    let ok = client.roundtrip(r#"{"op":"info"}"#);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
    server.stop();
}

#[test]
fn malformed_lines_get_errors_and_the_session_survives() {
    let server = start_server();
    let mut client = Client::connect(&server);
    let resp = client.roundtrip("this is not json");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    let resp = client.roundtrip(r#"{"op":"teleport"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    // Same connection still serves valid requests afterwards.
    let resp = client.roundtrip(r#"{"op":"chain","d":4,"steps":16,"seed":1}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    let result = resp.get("result").unwrap();
    assert_eq!(result.get("steps_completed").unwrap().as_usize(), Some(16));
    assert_eq!(result.get("failed").unwrap().as_bool(), Some(false));
    server.stop();
}

/// Occupy a single-worker server with a slow chain (hundreds of ms) so
/// requests sent meanwhile pile up behind it deterministically. Returns
/// once the occupant request is on the wire; join the handle to wait for
/// its completion.
fn occupy_worker(addr: SocketAddr) -> std::thread::JoinHandle<()> {
    let (sent_tx, sent_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let req = protocol::encode_chain_request("goomc64", 8, 100_000, 987_654);
        writer.write_all(req.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        sent_tx.send(()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("\"ok\":true"), "occupant failed: {resp}");
    });
    sent_rx.recv().expect("occupant request sent");
    // Give the loop a beat to hand the occupant to the worker.
    std::thread::sleep(Duration::from_millis(50));
    handle
}

fn one_shot(addr: SocketAddr, line: String) -> std::thread::JoinHandle<String> {
    std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    })
}

#[test]
fn concurrent_identical_requests_coalesce_onto_one_computation() {
    // One worker, occupied: identical requests arriving meanwhile must
    // coalesce onto one in-flight computation, and every waiter must see
    // the byte-identical response line.
    let server = Server::start(ServeConfig {
        port: 0,
        workers: 1,
        queue_depth: 16,
        batch_max: 1,
        cache_capacity: 64,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .unwrap();
    let occupant = occupy_worker(server.addr());
    let k = 5;
    let clients: Vec<_> = (0..k)
        .map(|_| {
            one_shot(
                server.addr(),
                protocol::encode_chain_request("goomc64", 6, 120, 4242),
            )
        })
        .collect();
    let lines: Vec<String> = clients.into_iter().map(|h| h.join().unwrap()).collect();
    occupant.join().unwrap();
    for line in &lines {
        assert_eq!(line, &lines[0], "coalesced responses must be byte-identical");
    }
    let doc = json::parse(&lines[0]).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{}", lines[0]);
    assert_eq!(doc.get("cached").unwrap().as_bool(), Some(false));
    // One leader computed; the other k-1 waiters coalesced. (The occupant
    // is the only other compute.)
    assert_eq!(server.counter("inflight_coalesced"), (k - 1) as u64);
    assert_eq!(server.counter("requests_ok"), 2);
    assert_eq!(server.counter("cache_misses"), (k + 1) as u64);
    // A repeat after completion is an ordinary cache hit.
    let repeat = one_shot(
        server.addr(),
        protocol::encode_chain_request("goomc64", 6, 120, 4242),
    )
    .join()
    .unwrap();
    let doc = json::parse(&repeat).unwrap();
    assert_eq!(doc.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(
        doc.get("result").unwrap(),
        json::parse(&lines[0]).unwrap().get("result").unwrap()
    );
    server.stop();
}

#[test]
fn queued_same_dimension_scans_batch_and_match_solo_results() {
    // One worker, occupied: same-dimension scans queue up behind it and the
    // worker drains them as one lockstep batch. Results must be exactly
    // the solo chunked-scan results.
    let server = Server::start(ServeConfig {
        port: 0,
        workers: 1,
        queue_depth: 16,
        batch_max: 8,
        cache_capacity: 64,
        retry_after_ms: 5,
        ..ServeConfig::default()
    })
    .unwrap();
    let occupant = occupy_worker(server.addr());
    let mut rng = rng_from_seed(321);
    // Different lengths, same dimension: still one batch.
    let payloads: Vec<Vec<GoomMat<f64>>> = (0..3)
        .map(|i| (0..(3 + 2 * i)).map(|_| GoomMat::randn(3, 3, &mut rng)).collect())
        .collect();
    let clients: Vec<_> = payloads
        .iter()
        .map(|mats| one_shot(server.addr(), protocol::encode_scan_request(mats, 4)))
        .collect();
    let lines: Vec<String> = clients.into_iter().map(|h| h.join().unwrap()).collect();
    occupant.join().unwrap();
    for (mats, line) in payloads.iter().zip(&lines) {
        let doc = json::parse(line).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{line}");
        let result = doc.get("result").unwrap();
        assert_eq!(result.get("len").unwrap().as_usize(), Some(mats.len()));
        let combine =
            |earlier: &GoomMat<f64>, later: &GoomMat<f64>| lmme(later, earlier);
        let local = scan_par_chunked(mats, combine, 4, 1);
        let local = local.last().unwrap();
        let logmag = result.get("logmag").unwrap().as_arr().unwrap();
        let sign = result.get("sign").unwrap().as_arr().unwrap();
        for i in 0..9 {
            let got = logmag[i].as_f64().unwrap_or(f64::NEG_INFINITY);
            assert_eq!(got, local.logmag[i], "logmag[{i}]");
            assert_eq!(sign[i].as_f64().unwrap(), local.sign[i], "sign[{i}]");
        }
    }
    assert!(
        server.counter("scan_batches") >= 1,
        "queued scans should have batched: {}",
        server.metrics_summary()
    );
    server.stop();
}

#[test]
fn concurrent_same_shape_requests_agree_with_solo_results() {
    // Many clients fire same-shape GOOM chain requests simultaneously; the
    // pool may fold them into stacked batches. Every response must equal
    // the solo (unbatched, cache-cold) result for its seed.
    let server = start_server();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = server.addr();
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                let req = protocol::encode_chain_request("goomc64", 6, 80, 5000 + i);
                writer.write_all(req.as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                writer.flush().unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                (i, resp)
            })
        })
        .collect();
    for h in handles {
        let (i, resp) = h.join().unwrap();
        let doc = json::parse(resp.trim()).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        let got = doc
            .get("result")
            .unwrap()
            .get("final_max_logmag")
            .unwrap()
            .as_f64()
            .unwrap();
        let solo = goomrs::chain::run_chain(
            goomrs::chain::Method::GoomC64,
            6,
            80,
            5000 + i,
            None,
        )
        .unwrap();
        let diff = (got - solo.final_max_logmag).abs();
        assert!(diff < 1e-3, "seed {}: served {got} vs solo {}", 5000 + i, solo.final_max_logmag);
    }
    server.stop();
}

#[test]
fn wire_id_echoes_byte_exactly_and_tracing_leaves_results_bit_identical() {
    let server = start_server();
    let stream = TcpStream::connect(server.addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream);
    let mut raw = move |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "server closed unexpectedly");
        resp.trim_end().to_string()
    };

    // id echo leads the response bytes on introspection, compute, and
    // request-level error paths alike.
    let resp = raw(r#"{"op":"info","id":"shard-info"}"#);
    assert!(resp.starts_with(r#"{"id":"shard-info","#), "{resp}");
    let resp = raw(r#"{"op":"chain","d":4,"steps":20,"seed":31,"id":9007}"#);
    assert!(resp.starts_with(r#"{"id":9007,"#), "{resp}");
    let resp = raw(r#"{"op":"lle","system":"narnia","id":"err-1"}"#);
    assert!(resp.starts_with(r#"{"id":"err-1","#), "errors echo too: {resp}");
    assert_eq!(
        json::parse(&resp).unwrap().get("ok").unwrap().as_bool(),
        Some(false)
    );
    // Lines that never decode into a request answer id-less (the decoder
    // can't trust any field of a line it rejected).
    let resp = raw(r#"{"op":"teleport","id":"lost"}"#);
    let doc = json::parse(&resp).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false));
    assert!(doc.get("id").is_none(), "rejected line must stay id-less: {resp}");
    // Invalid ids (wrong type) are rejected, not silently dropped.
    let resp = raw(r#"{"op":"info","id":true}"#);
    assert_eq!(
        json::parse(&resp).unwrap().get("ok").unwrap().as_bool(),
        Some(false)
    );

    // Bit-identity: the same cold request on an identically-configured
    // server, computed with the trace gate wide open (sample=1, which also
    // records span events for the minted id), must produce the exact same
    // result document as the gate-closed run.
    let cold = raw(&protocol::encode_chain_request("goomc64", 6, 120, 424242));
    let cold = json::parse(&cold).unwrap();
    assert_eq!(cold.get("cached").unwrap().as_bool(), Some(false));
    goomrs::obs::set_sample(1);
    let traced_server = start_server();
    let stream = TcpStream::connect(traced_server.addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream);
    let req = protocol::encode_chain_request("goomc64", 6, 120, 424242);
    writer.write_all(req.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let traced = json::parse(resp.trim()).unwrap();
    assert_eq!(traced.get("cached").unwrap().as_bool(), Some(false));
    assert_eq!(
        cold.get("result").unwrap(),
        traced.get("result").unwrap(),
        "tracing must not perturb results"
    );
    // The traced run actually recorded spans, reachable via the trace op.
    let trace = json::parse(&{
        let stream = TcpStream::connect(traced_server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writer.write_all(b"{\"op\":\"trace\",\"limit\":100000}\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    })
    .unwrap();
    goomrs::obs::set_sample(0);
    assert_eq!(trace.get("ok").unwrap().as_bool(), Some(true));
    let result = trace.get("result").unwrap();
    assert!(result.get("sample").unwrap().as_f64().is_some());
    let spans = result.get("spans").unwrap().as_arr().unwrap();
    assert!(
        spans.iter().any(|s| {
            s.get("stage").and_then(Json::as_str) == Some("kernel")
                && s.get("tier").and_then(Json::as_str) == Some("server")
        }),
        "sampled compute must have recorded a kernel span"
    );
    traced_server.stop();
    server.stop();
}
