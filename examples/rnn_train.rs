//! END-TO-END driver: train the paper's §4.3 GOOM-SSM RNN on the
//! copy-memory workload through the full three-layer stack —
//! Pallas/JAX-authored train step, AOT-lowered to HLO text, executed from
//! Rust via PJRT — and report the loss curve plus recall accuracy.
//!
//! This is the repository's proof that all layers compose: Python never
//! runs here; the entire fwd+bwd+Adam update is the compiled artifact.
//!
//! ```bash
//! make artifacts && cargo run --release --example rnn_train -- [--steps=300]
//! ```

use goomrs::rnn::{CopyMemoryTask, Trainer};
use goomrs::runtime::Engine;
use goomrs::util::cli::Args;
use goomrs::util::csv::CsvWriter;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps = args.get_usize("steps", 300)?;
    let seed = args.get_u64("seed", 12345)?;

    let engine = Engine::from_default_artifacts()?;
    println!("PJRT platform: {}", engine.platform());
    let mut trainer = Trainer::new(&engine, "copy")?;
    let spec = trainer.spec.clone();
    println!(
        "model: {} params | vocab {} | seq {} | batch {} | mode {}",
        spec.n_params, spec.vocab, spec.seq_len, spec.batch, spec.mode
    );

    let mut task = CopyMemoryTask::new(spec.vocab, spec.seq_len, spec.batch, seed);
    let mut csv = CsvWriter::create("runs/rnn_train_example.csv", &["step", "loss"])?;
    let chance = ((spec.vocab - 2) as f64).ln();
    println!("chance-level recall loss ≈ {chance:.3} nats\n");

    let t0 = Instant::now();
    let mut tokens_seen = 0usize;
    for s in 0..steps {
        let batch = task.next_batch();
        let loss = trainer.train_step(&batch.tokens, &batch.targets)?;
        tokens_seen += batch.tokens.len();
        csv.row(&[s.to_string(), loss.to_string()])?;
        if s % 25 == 0 || s + 1 == steps {
            println!("step {s:>5}  loss {loss:.4}");
        }
        assert!(loss.is_finite(), "non-finite loss — stabilization-free claim violated");
    }
    csv.flush()?;
    let elapsed = t0.elapsed().as_secs_f64();

    let probe = task.next_batch();
    let acc = trainer.copy_recall_accuracy(&probe.tokens, task.payload_len)?;
    let first = trainer.loss_history[0];
    let last = *trainer.loss_history.last().unwrap();
    println!("\n=== summary ===");
    println!("loss: {first:.4} -> {last:.4} over {steps} steps");
    println!("recall accuracy: {:.1}% (chance {:.1}%)", acc * 100.0,
             100.0 / (spec.vocab - 2) as f64);
    println!(
        "throughput: {:.0} tokens/s  ({:.1} ms/step)",
        tokens_seen as f64 / elapsed,
        1e3 * elapsed / steps as f64
    );
    println!("loss curve: runs/rnn_train_example.csv");
    anyhow::ensure!(last < first, "loss did not decrease");
    Ok(())
}
