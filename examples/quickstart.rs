//! Quickstart: the GOOM public API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use goomrs::goom::{goom_dot, lmme, scan_seq, Goom, GoomMat};
use goomrs::linalg::Mat;
use goomrs::rng::rng_from_seed;

fn main() -> anyhow::Result<()> {
    // --- scalars ---------------------------------------------------------
    // A GOOM represents sign · exp(logmag): any real, at absurd magnitudes.
    let a = Goom::<f64>::from_real(-3.0);
    let b = Goom::<f64>::from_real(4.0);
    println!("(-3) * 4       = {}", a.mul(b).to_f64());
    println!("(-3) + 4       = {}", a.add(b).to_f64());

    // The paper's Example 2: exp(1000)·exp(1000) overflows f64 as a real
    // number but is just logmag 2000 as a GOOM.
    let huge = Goom::<f64>::from_logmag(1000.0);
    let sq = huge.mul(huge);
    println!("exp(1000)^2    = exp({})  [f64 would overflow at exp(709)]", sq.logmag);

    // Dot products become signed log-sum-exps:
    let v = vec![Goom::<f64>::from_logmag(1000.0); 3];
    println!("huge dot       = exp({:.4})", goom_dot(&v, &v).logmag);

    // --- matrices and LMME ----------------------------------------------
    let mut rng = rng_from_seed(0);
    let x = Mat::randn(4, 4, &mut rng);
    let y = Mat::randn(4, 4, &mut rng);
    let gx = GoomMat::<f64>::from_mat(&x);
    let gy = GoomMat::<f64>::from_mat(&y);
    let real = x.matmul(&y);
    let via_goom = lmme(&gx, &gy).to_mat();
    println!(
        "LMME == matmul: max |Δ| = {:.2e}",
        real.data
            .iter()
            .zip(&via_goom.data)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f64, f64::max)
    );

    // --- a chain that floats cannot survive ------------------------------
    // 2000 random-normal matmuls: element magnitudes reach ~exp(2000+).
    let chain: Vec<GoomMat<f64>> =
        (0..2000).map(|_| GoomMat::randn(4, 4, &mut rng)).collect();
    let states = scan_seq(&chain, &|earlier, later| lmme(later, earlier));
    let last = states.last().unwrap();
    println!(
        "2000-step chain: max logmag = {:.1} (f64 dies at 709.8)",
        last.max_logmag()
    );

    // --- the AOT path (optional: needs `make artifacts`) ------------------
    match goomrs::runtime::Engine::from_default_artifacts() {
        Ok(engine) => {
            let (al, asg) = goomrs::runtime::goommat_to_literals(&GoomMat::<f32>::from_mat(&{
                let mut r = rng_from_seed(1);
                Mat::randn(16, 16, &mut r)
            }))?;
            let (bl, bsg) = goomrs::runtime::goommat_to_literals(&GoomMat::<f32>::from_mat(&{
                let mut r = rng_from_seed(2);
                Mat::randn(16, 16, &mut r)
            }))?;
            let out = engine.run("lmme_d16", &[al, asg, bl, bsg])?;
            println!(
                "AOT LMME on PJRT ({}) returned {} outputs — three-layer stack OK",
                engine.platform(),
                out.len()
            );
        }
        Err(_) => println!("(run `make artifacts` to enable the AOT/PJRT demo)"),
    }
    Ok(())
}
