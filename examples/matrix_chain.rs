//! Fig. 1 in miniature: watch Float32 and Float64 chains die while the
//! GOOM chain sails on — including through the AOT/PJRT artifact.
//!
//! ```bash
//! cargo run --release --example matrix_chain -- [--d=16] [--steps=20000]
//! ```

use goomrs::chain::{empirical_log_growth_rate, run_chain, Method};
use goomrs::runtime::Engine;
use goomrs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let d = args.get_usize("d", 16)?;
    let steps = args.get_usize("steps", 20_000)?;
    let seed = args.get_u64("seed", 42)?;

    let growth = empirical_log_growth_rate(d, 200, seed);
    println!("d = {d}: empirical log-magnitude growth ≈ {growth:.3}/step");
    println!("predicted failure: f32 ≈ step {:.0}, f64 ≈ step {:.0}\n",
             88.7 / growth, 709.8 / growth);

    let engine = Engine::from_default_artifacts().ok();
    let methods: Vec<Method> = [
        Some(Method::F32),
        Some(Method::F64),
        Some(Method::GoomC64),
        Some(Method::GoomC128),
        engine.as_ref().and_then(|_| {
            if [8usize, 16, 32].contains(&d) { Some(Method::GoomHlo) } else { None }
        }),
    ]
    .into_iter()
    .flatten()
    .collect();

    for m in methods {
        let cap = match m {
            Method::F32 | Method::F64 => steps,
            _ => steps.min(4096), // GOOMs always finish; cap for demo runtime
        };
        let res = run_chain(m, d, cap, seed, engine.as_ref())?;
        let status = if res.failed {
            format!("DIED at step {}", res.steps_completed)
        } else {
            format!(
                "completed {} steps, max logmag {:.1}",
                res.steps_completed, res.final_max_logmag
            )
        };
        println!("{:<28} {}", m.label(), status);
    }
    Ok(())
}
