//! Estimate the Lyapunov spectrum of any system in the dataset, three ways:
//! sequential QR baseline, the paper's parallel GOOM scan, and (for 3-D
//! systems) the AOT/PJRT spectrum artifact.
//!
//! ```bash
//! cargo run --release --example lyapunov_spectrum -- lorenz [--steps=8000]
//! cargo run --release --example lyapunov_spectrum -- --list
//! ```

use goomrs::dynsys;
use goomrs::goom::GoomMat;
use goomrs::lyapunov::{self, ParallelOpts};
use goomrs::runtime::{goommat_stack_to_literals, lit_scalar_f32, Engine};
use goomrs::util::cli::Args;
use goomrs::util::timing::{fmt_duration, time_once};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    if args.flag("list") {
        for s in dynsys::all_systems() {
            println!("{}", s.name());
        }
        return Ok(());
    }
    let name = args.subcommand.clone().unwrap_or_else(|| "lorenz".into());
    let steps = args.get_usize("steps", 8000)?;
    let burn = args.get_usize("burn", 1000)?;
    let sys = dynsys::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown system '{name}' (try --list)"))?;

    println!("system: {} (dim {}, dt {})", sys.name(), sys.dim(), sys.dt());
    let x0 = dynsys::burn_in(sys.as_ref(), burn);
    let (jacs, _) = dynsys::jacobian_chain(sys.as_ref(), &x0, steps);
    let dt = sys.dt();

    let (t_seq, seq) = time_once(|| lyapunov::spectrum_sequential(&jacs, dt));
    println!("\nsequential QR baseline        [{}]", fmt_duration(t_seq));
    println!("  Λ = {seq:+.4?}");

    let opts = ParallelOpts::default();
    let (t_par, par) = time_once(|| lyapunov::spectrum_parallel(&jacs, dt, &opts));
    println!("parallel GOOM scan (1 core)   [{}]", fmt_duration(t_par));
    println!("  Λ = {par:+.4?}");

    let (t_lle, lle) = time_once(|| lyapunov::lle_parallel(&jacs, dt, 64, 4));
    println!("parallel LLE (eq. 24)         [{}]", fmt_duration(t_lle));
    println!("  λ1 = {lle:+.4}");
    if let Some(reference) = sys.reference_lle() {
        println!("  λ1 literature ≈ {reference:+.4}");
    }

    // AOT spectrum artifact (3-D systems, 256-step window).
    if sys.dim() == 3 && jacs.len() >= 256 {
        if let Ok(engine) = Engine::from_default_artifacts() {
            let stack: Vec<GoomMat<f32>> =
                jacs[..256].iter().map(GoomMat::<f32>::from_mat).collect();
            let (jl, js) = goommat_stack_to_literals(&stack)?;
            let (t_hlo, out) = time_once(|| {
                engine.run("spectrum_d3_T256", &[jl, js, lit_scalar_f32(dt as f32)])
            });
            let out = out?;
            let lam = out[0].to_vec::<f32>()?;
            let resets = out[1].to_vec::<f32>()?[0];
            println!(
                "AOT spectrum artifact (T=256) [{}]  (selective resets fired: {resets})",
                fmt_duration(t_hlo)
            );
            println!("  Λ = {lam:+.4?}  (short window: expect coarser estimates)");
        }
    }
    Ok(())
}
