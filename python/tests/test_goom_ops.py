"""Layer-2 GOOM op validation: maps, arithmetic, LSE, LMME, custom VJPs."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import goom
from compile.kernels.ref import lmme_ref


def test_to_from_goom_roundtrip():
    x = jnp.array([0.0, 1.0, -1.0, 3.5e10, -2.75e-20, 17.0], jnp.float32)
    l, s = goom.to_goom(x)
    back = goom.from_goom(l, s)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6, atol=1e-35)


def test_zero_maps_to_floor_and_back():
    l, s = goom.to_goom(jnp.zeros((3,), jnp.float32))
    assert np.all(np.asarray(l) <= goom.LOG_FLOOR_F32 + 1e-3)
    assert np.all(np.asarray(s) == 1.0)  # zero is non-negative by convention
    back = goom.from_goom(l, s)
    np.testing.assert_allclose(np.asarray(back), 0.0, atol=1e-37)


def test_goom_mul_add_match_reals():
    rng = np.random.RandomState(0)
    x = rng.randn(100).astype("float32") * 10
    y = rng.randn(100).astype("float32") * 10
    gx, gy = goom.to_goom(jnp.array(x)), goom.to_goom(jnp.array(y))
    prod = goom.from_goom(*goom.goom_mul(gx, gy))
    np.testing.assert_allclose(np.asarray(prod), x * y, rtol=1e-5, atol=1e-5)
    ssum = goom.from_goom(*goom.goom_add(gx, gy))
    np.testing.assert_allclose(np.asarray(ssum), x + y, rtol=1e-4, atol=1e-4)


def test_goom_add_beyond_float_range():
    # exp(1000) + exp(1000) = 2 exp(1000) — unrepresentable as f32 reals.
    l = jnp.full((2,), 1000.0, jnp.float32)
    s = jnp.ones((2,), jnp.float32)
    ol, osg = goom.goom_add((l[:1], s[:1]), (l[1:], s[1:]))
    np.testing.assert_allclose(float(ol[0]), 1000.0 + np.log(2.0), rtol=1e-6)
    assert float(osg[0]) == 1.0


def test_goom_lse_matches_sum():
    rng = np.random.RandomState(1)
    x = rng.randn(6, 50).astype("float32")
    g = goom.to_goom(jnp.array(x))
    ol, osg = goom.goom_lse(*g, axis=-1)
    got = np.asarray(goom.from_goom(ol, osg))
    np.testing.assert_allclose(got, x.sum(-1), rtol=1e-4, atol=1e-4)


def test_lmme_matches_oracle_and_batches():
    rng = np.random.RandomState(2)
    a = rng.randn(5, 8, 4).astype("float32")
    b = rng.randn(5, 4, 6).astype("float32")
    ga, gb = goom.to_goom(jnp.array(a)), goom.to_goom(jnp.array(b))
    ol, osg = goom.lmme(ga, gb)
    for i in range(5):
        rl, rs = lmme_ref(*goom.to_goom(jnp.array(a[i])), *goom.to_goom(jnp.array(b[i])))
        np.testing.assert_allclose(np.asarray(ol[i]), np.asarray(rl), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(osg[i]), np.asarray(rs))


def test_lmme_exact_agrees_with_compromise():
    rng = np.random.RandomState(3)
    a = (rng.randn(6, 6) * 2).astype("float32")
    b = (rng.randn(6, 6) * 2).astype("float32")
    ga, gb = goom.to_goom(jnp.array(a)), goom.to_goom(jnp.array(b))
    l1, s1 = goom.lmme(ga, gb)
    l2, s2 = goom.lmme_exact(ga, gb)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_to_goom_gradient_is_finite_at_zero():
    # eq. 5/6: gradient must be finite (and non-zero) even at x = 0.
    def f(x):
        l, s = goom.to_goom(x)
        return jnp.sum(l)

    g = jax.grad(f)(jnp.zeros((4,), jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.all(np.asarray(g) > 0)  # 1/(0 + eps), sign +


def test_from_goom_gradient_nonzero_at_floor():
    # eq. 8: derivative shifted away from zero by ±eps.
    def f(l):
        return jnp.sum(goom.from_goom(l, jnp.ones_like(l)))

    g = jax.grad(f)(jnp.full((4,), goom.LOG_FLOOR_F32, jnp.float32))
    assert np.all(np.asarray(g) != 0.0)


def test_roundtrip_gradient_chain():
    # Gradients flow through R -> C' -> R (the paper's backprop claim).
    def f(x):
        l, s = goom.to_goom(x)
        l2, s2 = goom.goom_mul((l, s), (l, s))  # x^2 in goom space
        return jnp.sum(goom.from_goom(l2, s2))

    x = jnp.array([2.0, -3.0], jnp.float32)
    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 2.0 * np.asarray(x), rtol=1e-3)


def test_rescale_export_bounds():
    l = jnp.array([[5000.0, 4990.0], [4980.0, 5000.0]], jnp.float32)
    s = jnp.array([[1.0, -1.0], [1.0, 1.0]], jnp.float32)
    x, c = goom.rescale_export(l, s, axis=-1)
    assert np.all(np.abs(np.asarray(x)) <= np.exp(2.0) + 1e-5)
    assert float(np.max(np.abs(np.asarray(x)))) > 1.0  # max element ~ e^2


@settings(max_examples=30, deadline=None)
@given(
    # Shifts below ≈ -165 push logmags under the finite zero floor
    # (-174.673); entries there ARE semantic zeros, so invariance
    # legitimately breaks. Stay above the floor.
    shift=st.floats(min_value=-160, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_lmme_magnitude_invariance(shift, seed):
    """LMME(A'+c, B') == LMME(A', B') + c elementwise in log space: shifting
    logmags must shift the output exactly, at any magnitude."""
    rng = np.random.RandomState(seed)
    al = rng.randn(4, 4).astype("float32")
    asg = np.where(rng.randn(4, 4) < 0, -1.0, 1.0).astype("float32")
    bl = rng.randn(4, 4).astype("float32")
    bsg = np.where(rng.randn(4, 4) < 0, -1.0, 1.0).astype("float32")
    base_l, base_s = goom.lmme((jnp.array(al), jnp.array(asg)),
                               (jnp.array(bl), jnp.array(bsg)))
    shift_l, shift_s = goom.lmme((jnp.array(al + shift), jnp.array(asg)),
                                 (jnp.array(bl), jnp.array(bsg)))
    # Tolerance floor reflects f32 input quantization: (al + shift) rounds
    # at ulp(shift) ~ 1.2e-7*|shift| per entry, amplified ~2-4x through the
    # scaled exp/sum/log pipeline.
    np.testing.assert_allclose(np.asarray(shift_l) - shift, np.asarray(base_l),
                               rtol=0, atol=max(2e-4, 1e-6 * abs(shift)))
    np.testing.assert_array_equal(np.asarray(shift_s), np.asarray(base_s))
