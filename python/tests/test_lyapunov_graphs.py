"""Validation of the AOT Lyapunov graphs on systems with known exponents."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.lyapunov import (col_log_norms, make_lle_scan, make_spectrum,
                              max_pairwise_col_cosine, mgs_qr,
                              orthonormalize_goom)


def goomify(x):
    return (np.log(np.maximum(np.abs(x), 1e-30)).astype("float32"),
            np.where(x < 0, -1.0, 1.0).astype("float32"))


def triangular_chain(T=256, d=3):
    j = np.diag([1.1, 0.9, 0.5]).astype("float32")
    j[0, 1] = 0.05
    j[1, 2] = -0.03
    stack = np.tile(j, (T, 1, 1))
    jl, js = goomify(stack)
    jl = np.where(stack == 0, -174.673, jl).astype("float32")
    return jl, js


def test_mgs_qr_invariants():
    rng = np.random.RandomState(0)
    x = rng.randn(7, 5, 5).astype("float32")
    q, r = mgs_qr(jnp.array(x))
    q, r = np.asarray(q), np.asarray(r)
    for b in range(7):
        np.testing.assert_allclose(q[b] @ r[b], x[b], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(q[b].T @ q[b], np.eye(5), atol=1e-4)
        assert np.all(np.diag(r[b]) >= 0)
        assert np.allclose(np.tril(r[b], -1), 0, atol=1e-6)


def test_col_log_norms_matches_real():
    rng = np.random.RandomState(1)
    x = rng.randn(6, 4).astype("float32")
    xl, _ = goomify(x)
    got = np.asarray(col_log_norms(jnp.array(xl)))
    expect = np.log(np.linalg.norm(x, axis=0))
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)


def test_max_pairwise_cosine_detects_colinearity():
    x = np.array([[1.0, 1.001, 0.0], [1.0, 0.999, 1.0], [0.5, 0.5, -1.0]],
                 dtype="float32")
    xl, xs = goomify(x)
    cos = float(max_pairwise_col_cosine(jnp.array(xl), jnp.array(xs)))
    assert cos > 0.999
    eye_l, eye_s = goomify(np.eye(3).astype("float32") + 0.0)
    eye_l = np.where(np.eye(3) == 0, -174.673, eye_l).astype("float32")
    cos_eye = float(max_pairwise_col_cosine(jnp.array(eye_l), jnp.array(eye_s)))
    assert cos_eye < 1e-3


def test_orthonormalize_goom_output_is_orthonormal():
    rng = np.random.RandomState(2)
    x = rng.randn(4, 4).astype("float32") * 1e3
    xl, xs = goomify(x)
    # Push magnitudes far beyond floats: add 5000 to logmags.
    ql, qs = orthonormalize_goom(jnp.array(xl + 5000.0), jnp.array(xs))
    q = np.asarray(qs) * np.exp(np.asarray(ql))
    np.testing.assert_allclose(q.T @ q, np.eye(4), atol=1e-4)


def test_lle_graph_on_triangular_system():
    T, d = 256, 3
    jl, js = triangular_chain(T, d)
    lle = jax.jit(make_lle_scan(d, T))
    u0 = (np.ones(3) / np.sqrt(3)).astype("float32")
    val, trace = lle(jl, js, u0, jnp.float32(1.0))
    assert abs(float(val) - np.log(1.1)) < 0.02
    # Trace grows ~linearly with slope ln(1.1).
    slope = (float(trace[-1]) - float(trace[100])) / (T - 101)
    assert abs(slope - np.log(1.1)) < 0.01


def test_spectrum_graph_recovers_all_exponents():
    T, d = 256, 3
    jl, js = triangular_chain(T, d)
    spec = jax.jit(make_spectrum(d, T))
    lam, nresets = spec(jl, js, jnp.float32(1.0))
    got = np.sort(np.asarray(lam))[::-1]
    expect = np.sort(np.log([1.1, 0.9, 0.5]))[::-1]
    np.testing.assert_allclose(got, expect, atol=0.05)
    assert float(nresets) > 0  # colinearity resets must fire


def test_spectrum_graph_contractive_system_no_blowup():
    # All-contracting system: states shrink toward zero magnitude; graph
    # must neither overflow nor produce NaN.
    T, d = 128, 3
    j = (0.5 * np.eye(3)).astype("float32")
    stack = np.tile(j, (T, 1, 1))
    jl = np.where(stack == 0, -174.673,
                  np.log(np.maximum(np.abs(stack), 1e-30))).astype("float32")
    js = np.ones_like(jl)
    spec = jax.jit(make_spectrum(d, T))
    lam, _ = spec(jl, js, jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(lam), np.log(0.5), atol=0.02)
