"""RNN model validation: shapes, gradient health, trainability, and the
no-stabilization claim (finite states/gradients with spectral radius > 1)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def small_cfg(**kw):
    defaults = dict(vocab=8, d_model=16, n_heads=2, d_head=4, d_state=4,
                    n_layers=2, seq_len=12, batch=4)
    defaults.update(kw)
    return model.RnnConfig(**defaults)


def test_forward_shapes():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((cfg.batch, cfg.seq_len), jnp.int32)
    logits = model.forward(cfg, p, toks)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_names_cover_params():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    assert set(model.param_names(cfg)) == set(p.keys())
    # ordering is deterministic
    assert model.param_names(cfg) == model.param_names(cfg)


def test_loss_decreases_on_fixed_batch():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(1))
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    ts = jax.jit(model.make_train_step(cfg))
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    losses = []
    for i in range(15):
        p, m, v, loss = ts(p, m, v, jnp.array(i, jnp.int32), toks, tgts)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 0.8, losses


def test_gradients_finite_with_unstable_transition():
    """The headline §4.3 claim: non-diagonal A with spectral radius > 1,
    NO stabilization, and both forward states and gradients stay finite."""
    cfg = small_cfg(seq_len=64, n_layers=1)
    p = model.init_params(cfg, jax.random.PRNGKey(3))
    # Scale A to spectral radius ~1.5: the float recurrence would reach
    # 1.5^64 ~ 2e11 per head state; deeper stacks would overflow f32 fast.
    a = np.array(p["layer0.A"])  # writable copy
    for h in range(a.shape[0]):
        eig = np.max(np.abs(np.linalg.eigvals(a[h])))
        a[h] *= 1.5 / eig
    p["layer0.A"] = jnp.array(a)
    toks = jax.random.randint(jax.random.PRNGKey(4), (cfg.batch, cfg.seq_len),
                              0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)

    def loss(params):
        return model.loss_fn(cfg, params, toks, tgts)

    val, grads = jax.value_and_grad(loss)(p)
    assert np.isfinite(float(val))
    for k, g in grads.items():
        assert np.all(np.isfinite(np.asarray(g))), f"non-finite grad in {k}"
    # And gradients actually flow into the recurrent transition:
    assert float(jnp.max(jnp.abs(grads["layer0.A"]))) > 0


def test_classification_mode():
    cfg = small_cfg(mode="cls")
    p = model.init_params(cfg, jax.random.PRNGKey(5))
    ts = jax.jit(model.make_train_step(cfg))
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    toks = jax.random.randint(jax.random.PRNGKey(6), (cfg.batch, cfg.seq_len),
                              0, cfg.vocab)
    tgts = jnp.array([1, 0, 3, 2], jnp.int32)
    losses = []
    for i in range(20):
        p, m, v, loss = ts(p, m, v, jnp.array(i, jnp.int32), toks, tgts)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_train_step_flat_wrapper_roundtrip():
    """The aot.py flattening contract: flat-arg wrapper == pytree step."""
    from compile.aot import COPY_CFG  # noqa: F401  (import sanity)
    cfg = small_cfg()
    names = model.param_names(cfg)
    p = model.init_params(cfg, jax.random.PRNGKey(7))
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    toks = jax.random.randint(jax.random.PRNGKey(8), (cfg.batch, cfg.seq_len),
                              0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    step = jnp.array(0, jnp.int32)
    p2, m2, v2, loss = model.make_train_step(cfg)(p, m, v, step, toks, tgts)

    flat_in = [p[k] for k in names] + [m[k] for k in names] + \
              [v[k] for k in names] + [step, toks, tgts]

    def train_flat(*args):
        n = len(names)
        params = dict(zip(names, args[:n]))
        mm = dict(zip(names, args[n:2 * n]))
        vv = dict(zip(names, args[2 * n:3 * n]))
        s, tk, tg = args[3 * n:]
        np_, nm, nv, l = model.make_train_step(cfg)(params, mm, vv, s, tk, tg)
        return tuple(np_[k] for k in names) + (l,)

    out = train_flat(*flat_in)
    for k, got in zip(names, out[:-1]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(p2[k]),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(out[-1]), float(loss), rtol=1e-6)
