"""AOT artifact contract tests: gbin container roundtrip, HLO text
generation, and manifest shape (no full re-lowering of the big graphs)."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, goom


def test_gbin_roundtrip():
    tensors = [
        ("param.w", np.arange(12, dtype="float32").reshape(3, 4)),
        ("step", np.array([7], dtype="int32")),
        ("big", np.random.RandomState(0).randn(5, 2, 2).astype("float64")),
    ]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.gbin")
        aot.write_gbin(path, tensors)
        # hand-rolled reader mirroring rust runtime::gbin
        with open(path, "rb") as f:
            assert f.read(4) == b"GBIN"
            ver, count = struct.unpack("<II", f.read(8))
            assert ver == 1 and count == 3
            for name, arr in tensors:
                (nlen,) = struct.unpack("<I", f.read(4))
                assert f.read(nlen).decode() == name
                (tag,) = struct.unpack("<I", f.read(4))
                assert tag == {"float32": 0, "int32": 1, "float64": 2}[str(arr.dtype)]
                (ndim,) = struct.unpack("<I", f.read(4))
                dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
                assert dims == arr.shape
                data = np.frombuffer(f.read(arr.nbytes), dtype=arr.dtype).reshape(dims)
                np.testing.assert_array_equal(data, arr)


def test_hlo_text_lowering_of_small_graph():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    lowered = jax.jit(fn).lower(aot.spec((4, 4)), aot.spec((4, 4)))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_hlo_text_lowering_of_goom_lmme():
    def fn(al, asg, bl, bsg):
        return goom.lmme((al, asg), (bl, bsg))

    s = aot.spec((8, 8))
    text = aot.to_hlo_text(jax.jit(fn).lower(s, s, s, s))
    assert "HloModule" in text
    assert "dot(" in text  # the delegated real matmul is present


def test_manifest_written_by_make_artifacts():
    # `make artifacts` ran before the test suite (Makefile dependency);
    # validate the manifest the rust runtime will consume.
    manifest_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "..", "artifacts", "manifest.json")
    if not os.path.exists(manifest_path):
        import pytest
        pytest.skip("artifacts not built yet")
    with open(manifest_path) as f:
        manifest = json.load(f)
    arts = {a["name"]: a for a in manifest["artifacts"]}
    for required in ["lmme_d16", "chain_block_d8", "lle_scan_d3_T512",
                     "spectrum_d3_T256", "rnn_copy_train_step"]:
        assert required in arts, f"missing artifact {required}"
        entry = arts[required]
        assert os.path.exists(os.path.join(os.path.dirname(manifest_path),
                                           entry["path"]))
        assert len(entry["inputs"]) > 0
        for inp in entry["inputs"]:
            assert set(inp) == {"name", "dtype", "shape"}
    rnn = arts["rnn_copy_train_step"]
    # 3 * n_param_tensors + step/tokens/targets
    n = len(rnn["meta"]["param_names"])
    assert len(rnn["inputs"]) == 3 * n + 3
    assert rnn["outputs"][-1] == "loss"
