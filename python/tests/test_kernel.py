"""Layer-1 kernel validation: Pallas LMME vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel: hypothesis sweeps
shapes, tile configurations, magnitude regimes and signs, asserting
allclose against ref.lmme_ref and against the plain real matmul where
representable.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lmme import lmme_pallas, mxu_utilization_estimate, vmem_bytes
from compile.kernels.ref import lmme_ref


LOG_FLOOR = -174.673


def goomify(x):
    l = np.log(np.maximum(np.abs(x), 1e-38)).astype("float32")
    l = np.where(x == 0, LOG_FLOOR, l).astype("float32")  # exact zeros -> floor
    s = np.where(x < 0, -1.0, 1.0).astype("float32")
    return l, s


def run_both(a, b, **tiles):
    al, asg = goomify(a)
    bl, bsg = goomify(b)
    ol, osg = lmme_pallas(al, asg, bl, bsg, **tiles)
    rl, rs = lmme_ref(jnp.array(al), jnp.array(asg), jnp.array(bl), jnp.array(bsg))
    return (np.asarray(ol), np.asarray(osg)), (np.asarray(rl), np.asarray(rs))


def assert_goom_close(got, ref, rtol=2e-4, atol=2e-3):
    # Tolerances reflect f32 accumulation-order differences between the
    # tiled k-loop and the oracle's single reduction (worst on
    # cancellation-prone outputs whose logmag is far below the inputs').
    gl, gs = got
    rl, rs = ref
    # Where both are at the floor (zero), skip.
    live = ~((gl < -170) & (rl < -170))
    np.testing.assert_allclose(gl[live], rl[live], rtol=rtol, atol=atol)
    np.testing.assert_array_equal(gs[live], rs[live])


def test_single_tile_matches_ref_and_matmul():
    rng = np.random.RandomState(0)
    a = rng.randn(16, 16).astype("float32")
    b = rng.randn(16, 16).astype("float32")
    got, ref = run_both(a, b, bm=16, bn=16, bk=16)
    assert_goom_close(got, ref)
    real = np.asarray(got[1]) * np.exp(np.asarray(got[0]))
    np.testing.assert_allclose(real, a @ b, rtol=1e-4, atol=1e-5)


def test_multi_tile_grid_matches_ref():
    rng = np.random.RandomState(1)
    a = rng.randn(32, 48).astype("float32")
    b = rng.randn(48, 24).astype("float32")
    got, ref = run_both(a, b, bm=8, bn=8, bk=16)
    assert_goom_close(got, ref)


def test_huge_magnitudes_beyond_float32():
    # logmags around 1e4: the represented reals are ~exp(10000), far beyond
    # float32/float64; the kernel must stay exact in log space.
    rng = np.random.RandomState(2)
    al = (rng.randn(8, 8) * 3 + 10_000).astype("float32")
    asg = np.where(rng.randn(8, 8) < 0, -1.0, 1.0).astype("float32")
    bl = (rng.randn(8, 8) * 3 + 10_000).astype("float32")
    bsg = np.where(rng.randn(8, 8) < 0, -1.0, 1.0).astype("float32")
    ol, osg = lmme_pallas(al, asg, bl, bsg, bm=8, bn=8, bk=8)
    rl, rs = lmme_ref(jnp.array(al), jnp.array(asg), jnp.array(bl), jnp.array(bsg))
    assert np.all(np.isfinite(np.asarray(ol)))
    assert np.asarray(ol).max() > 19_000
    np.testing.assert_allclose(np.asarray(ol), np.asarray(rl), rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(osg), np.asarray(rs))


def test_zero_rows_stay_zero():
    rng = np.random.RandomState(3)
    a = rng.randn(8, 8).astype("float32")
    a[2, :] = 0.0
    b = rng.randn(8, 8).astype("float32")
    got, ref = run_both(a, b, bm=8, bn=8, bk=8)
    assert np.all(got[0][2, :] < -170), "zero row must stay at the floor"
    assert_goom_close(got, ref)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([4, 8, 16]),
    m=st.sampled_from([4, 8, 16]),
    shift=st.floats(min_value=-3000, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes_and_magnitudes(n, d, m, shift, seed):
    rng = np.random.RandomState(seed)
    al = (rng.randn(n, d) + shift).astype("float32")
    asg = np.where(rng.randn(n, d) < 0, -1.0, 1.0).astype("float32")
    bl = (rng.randn(d, m) + shift).astype("float32")
    bsg = np.where(rng.randn(d, m) < 0, -1.0, 1.0).astype("float32")
    bm = n if n <= 8 else n // 2
    bn = m if m <= 8 else m // 2
    bk = d
    ol, osg = lmme_pallas(al, asg, bl, bsg, bm=bm, bn=bn, bk=bk)
    rl, rs = lmme_ref(jnp.array(al), jnp.array(asg), jnp.array(bl), jnp.array(bsg))
    ol, rl = np.asarray(ol), np.asarray(rl)
    live = ~((ol < -170) & (np.asarray(rl) < -170))
    # relative-to-magnitude tolerance: logmags around |shift|
    tol = 3e-5 * max(1.0, abs(shift))
    np.testing.assert_allclose(ol[live], rl[live], rtol=0, atol=max(3e-3, tol))
    np.testing.assert_array_equal(np.asarray(osg)[live], np.asarray(rs)[live])


@settings(max_examples=10, deadline=None)
@given(
    bk=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_k_accumulation_tilings_agree(bk, seed):
    """Different k-tilings must produce the same accumulated product."""
    rng = np.random.RandomState(seed)
    a = rng.randn(8, 16).astype("float32")
    b = rng.randn(16, 8).astype("float32")
    got_tiled, _ = run_both(a, b, bm=8, bn=8, bk=bk)
    got_full, _ = run_both(a, b, bm=8, bn=8, bk=16)
    # different k-tilings reassociate the f32 accumulation; logmag
    # differences concentrate on cancellation-prone outputs
    np.testing.assert_allclose(got_tiled[0], got_full[0], rtol=1e-4, atol=2e-2)
    np.testing.assert_array_equal(got_tiled[1], got_full[1])


def test_rejects_misaligned_tiles():
    rng = np.random.RandomState(4)
    a, b = rng.randn(10, 8).astype("float32"), rng.randn(8, 8).astype("float32")
    al, asg = goomify(a)
    bl, bsg = goomify(b)
    with pytest.raises(AssertionError):
        lmme_pallas(al, asg, bl, bsg, bm=4, bn=4, bk=8)  # 10 % 4 != 0


def test_vmem_budget_of_default_tiles():
    # Default 128^3 tiles must fit 16 MiB VMEM with headroom.
    assert vmem_bytes(128, 128, 128) < 16 * 2**20 / 2


def test_mxu_utilization_estimate_reasonable():
    u = mxu_utilization_estimate(1024, 1024, 1024, 128, 128, 128)
    assert 0.9 < u <= 1.0, u  # large-d LMME is dot-dominated
    u_small = mxu_utilization_estimate(8, 8, 8, 8, 8, 8)
    assert u_small < u  # small tiles pay relatively more elementwise work
