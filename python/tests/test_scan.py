"""Prefix-scan validation: parallel associative scans vs sequential oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import goom
from compile.kernels.ref import affine_scan_ref, scan_chain_ref


def goomify(x):
    return (jnp.array(np.log(np.maximum(np.abs(x), 1e-38)).astype("float32")),
            jnp.array(np.where(x < 0, -1.0, 1.0).astype("float32")))


def test_matrix_chain_scan_matches_sequential_oracle():
    rng = np.random.RandomState(0)
    a = rng.randn(17, 4, 4).astype("float32")
    al, asg = goomify(a)
    pl, ps = goom.matrix_chain_scan((al, asg))
    rl, rs = scan_chain_ref(al, asg)
    live = np.asarray(rl) > -170
    np.testing.assert_allclose(np.asarray(pl)[live], np.asarray(rl)[live],
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(ps)[live], np.asarray(rs)[live])


def test_chain_scan_growth_matches_float_products_while_representable():
    rng = np.random.RandomState(1)
    a = rng.randn(20, 3, 3).astype("float64")
    al, asg = goomify(a)
    pl, ps = goom.matrix_chain_scan((al.astype(jnp.float32), asg))
    # Compare against float64 cumulative products (representable at T=20).
    h = np.eye(3)
    for t in range(20):
        h = a[t] @ h
        got = np.asarray(ps[t]) * np.exp(np.asarray(pl[t], dtype="float64"))
        np.testing.assert_allclose(got, h, rtol=5e-3, atol=1e-4)


def test_affine_scan_matches_sequential_oracle():
    rng = np.random.RandomState(2)
    a = rng.randn(9, 3, 3).astype("float32") * 0.7
    b = rng.randn(9, 3, 2).astype("float32")
    al, asg = goomify(a)
    bl, bsg = goomify(b)
    xl, xs = goom.goom_scan_affine((al, asg), (bl, bsg))
    refl, refs = affine_scan_ref(al, asg, bl, bsg)
    live = np.asarray(refl) > -170
    np.testing.assert_allclose(np.asarray(xl)[live], np.asarray(refl)[live],
                               rtol=1e-4, atol=2e-3)
    np.testing.assert_array_equal(np.asarray(xs)[live], np.asarray(refs)[live])


def test_affine_scan_matches_real_recurrence():
    rng = np.random.RandomState(3)
    a = (rng.randn(12, 3, 3) * 0.6).astype("float32")
    u = rng.randn(12, 3, 1).astype("float32")
    al, asg = goomify(a)
    bl, bsg = goomify(u)
    xl, xs = goom.goom_scan_affine((al, asg), (bl, bsg))
    x = np.zeros((3, 1))
    for t in range(12):
        x = a[t] @ x + u[t]
        got = np.asarray(xs[t]) * np.exp(np.asarray(xl[t]))
        np.testing.assert_allclose(got, x, rtol=1e-3, atol=1e-4)


def test_unstable_affine_scan_stays_finite_in_log_space():
    # Spectral radius ~3: the real recurrence overflows f32 after ~80 steps;
    # the GOOM scan must stay finite and match log-growth expectations.
    rng = np.random.RandomState(4)
    T = 400
    a = np.tile((3.0 * np.eye(3) + 0.1 * rng.randn(3, 3)).astype("float32"), (T, 1, 1))
    u = rng.randn(T, 3, 1).astype("float32")
    al, asg = goomify(a)
    bl, bsg = goomify(u)
    xl, xs = goom.goom_scan_affine((al, asg), (bl, bsg))
    assert np.all(np.isfinite(np.asarray(xl)))
    # Growth rate per step ≈ ln 3.
    growth = (float(jnp.max(xl[-1])) - float(jnp.max(xl[100]))) / (T - 101)
    assert abs(growth - np.log(3.0)) < 0.05, growth


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([2, 3, 5, 8, 16, 33]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_chain_scan_lengths(t, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(t, 3, 3).astype("float32")
    al, asg = goomify(a)
    pl, ps = goom.matrix_chain_scan((al, asg))
    rl, rs = scan_chain_ref(al, asg)
    live = np.asarray(rl) > -170
    np.testing.assert_allclose(np.asarray(pl)[live], np.asarray(rl)[live],
                               rtol=1e-3, atol=1e-3)
