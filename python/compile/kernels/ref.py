"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels and the
Layer-2 GOOM ops.

These are the ground truth the pytest/hypothesis suites compare the kernel
and the jitted graphs against. They favour clarity over speed, never leave
log space at full magnitude, and deliberately do NOT import compile.goom
(an oracle should be independent of the code under test).
"""

import jax.numpy as jnp

LOG_FLOOR_F32 = -174.673


def _signum_nonneg(x):
    return jnp.where(x < 0, -1.0, 1.0).astype(x.dtype)


def _signed_add(al, asg, bl, bsg):
    """Elementwise signed log-sum-exp of two GOOM arrays."""
    hi = jnp.maximum(al, bl)
    lo = jnp.minimum(al, bl)
    hs = jnp.where(al >= bl, asg, bsg)
    ls = jnp.where(al >= bl, bsg, asg)
    r = hs + ls * jnp.exp(lo - hi)
    absr = jnp.abs(r)
    out = hi + jnp.log(jnp.maximum(absr, 1e-30))
    out = jnp.where(absr > 0, out, LOG_FLOOR_F32)
    out = jnp.maximum(out, LOG_FLOOR_F32)
    return out, _signum_nonneg(r)


def lmme_ref(al, asg, bl, bsg):
    """Exact LMME (paper eq. 9): per-output-element signed log-sum-exp of
    the d pairwise logmag sums. Shapes: al [n,d], bl [d,m]."""
    s = al[:, :, None] + bl[None, :, :]  # [n, d, m]
    sg = asg[:, :, None] * bsg[None, :, :]
    m = jnp.max(s, axis=1, keepdims=True)
    m_safe = jnp.maximum(m, LOG_FLOOR_F32)
    acc = jnp.sum(sg * jnp.exp(s - m_safe), axis=1)
    absacc = jnp.abs(acc)
    out_l = jnp.squeeze(m_safe, 1) + jnp.log(jnp.maximum(absacc, 1e-30))
    out_l = jnp.where(absacc > 0, out_l, LOG_FLOOR_F32)
    out_l = jnp.maximum(out_l, LOG_FLOOR_F32)
    return out_l, _signum_nonneg(acc)


def matmul_log_ref(a, b):
    """Real matmul computed through log space (for error studies):
    log-map, exact LMME, exp-map."""
    al = jnp.log(jnp.maximum(jnp.abs(a), 1e-38))
    asg = _signum_nonneg(a)
    bl = jnp.log(jnp.maximum(jnp.abs(b), 1e-38))
    bsg = _signum_nonneg(b)
    ol, osg = lmme_ref(al, asg, bl, bsg)
    return osg * jnp.exp(ol)


def scan_chain_ref(al, asg):
    """Sequential reference for the GOOM matrix-chain prefix scan:
    H_t = A_t . H_{t-1}, computed with exact LMME. Shapes: [T, d, d]."""
    T = al.shape[0]
    outs_l, outs_s = [al[0]], [asg[0]]
    for t in range(1, T):
        ol, osg = lmme_ref(al[t], asg[t], outs_l[-1], outs_s[-1])
        outs_l.append(ol)
        outs_s.append(osg)
    return jnp.stack(outs_l), jnp.stack(outs_s)


def affine_scan_ref(a_l, a_s, b_l, b_s):
    """Sequential reference for the affine GOOM recurrence (paper eq. 26):
    x'_t = LSE(LMME(A'_t, x'_{t-1}), b'_t), with x'_0 = GOOM zero.

    Shapes: a [T,d,d], b [T,d,m]. Returns stacked states [T,d,m]."""
    T, d, m = b_l.shape
    xl = jnp.full((d, m), LOG_FLOOR_F32, a_l.dtype)
    xs = jnp.ones((d, m), a_l.dtype)
    outs_l, outs_s = [], []
    for t in range(T):
        pl, ps = lmme_ref(a_l[t], a_s[t], xl, xs)
        xl, xs = _signed_add(pl, ps, b_l[t], b_s[t])
        outs_l.append(xl)
        outs_s.append(xs)
    return jnp.stack(outs_l), jnp.stack(outs_s)
