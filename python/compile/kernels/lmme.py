"""Layer-1 Pallas kernel: tiled LMME over (logmag, sign) pairs.

The paper (§3.2, §6) notes its PyTorch implementation cannot express a
fused complex-typed kernel and therefore pays two elementwise passes plus a
cuBLAS call. Splitting GOOMs into (logmag, sign) real planes removes that
obstruction: this kernel fuses scale -> exponentiate -> dot -> log -> rescale
in one pass over VMEM-resident tiles, with the inner dot targeting the MXU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for
CUDA threadblocks/shared memory; here the BlockSpec expresses the HBM->VMEM
schedule. Block sizes are chosen so one (bm x bk) + (bk x bn) tile pair plus
the (bm x bn) f32 accumulator fit comfortably in 16 MiB VMEM with
double-buffering headroom (see ``vmem_bytes``).

The kernel MUST run with interpret=True in this environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are identical either way; pytest validates against ``ref.py``.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG_FLOOR_F32 = -174.673

# Default tile sizes (MXU-aligned: multiples of 128 for real deployments;
# smaller here so tests exercise multi-tile grids at toy shapes).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def vmem_bytes(bm, bn, bk, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step: A-tile pair + B-tile pair
    + f32 accumulator + output tile pair, times 2 for double buffering of
    the streamed inputs."""
    a_tiles = 2 * bm * bk * dtype_bytes  # logmag + sign
    b_tiles = 2 * bk * bn * dtype_bytes
    acc = bm * bn * 4
    out_tiles = 2 * bm * bn * dtype_bytes
    return 2 * (a_tiles + b_tiles) + acc + out_tiles


def _lmme_kernel(ascale_ref, bscale_ref, al_ref, asg_ref, bl_ref, bsg_ref,
                 ol_ref, osg_ref, *, nsteps_k):
    """Grid = (m_blocks, n_blocks, k_blocks); k innermost accumulates."""
    k = pl.program_id(2)

    # Row/col scaling constants for this tile (precomputed in L2; eq. 11).
    ascale = ascale_ref[...]  # [bm, 1]
    bscale = bscale_ref[...]  # [1, bn]

    # Scale and exponentiate the input tiles in VMEM (fused; the paper's
    # implementation pays a separate elementwise pass through HBM for this).
    ea = asg_ref[...] * jnp.exp(al_ref[...] - ascale)
    eb = bsg_ref[...] * jnp.exp(bl_ref[...] - bscale)

    # MXU tile dot, f32 accumulation.
    partial_prod = jnp.dot(ea, eb, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        ol_ref[...] = partial_prod

    @pl.when(k > 0)
    def _accum():
        ol_ref[...] += partial_prod

    # Last k step: convert the accumulated real product back to GOOM form.
    @pl.when(k == nsteps_k - 1)
    def _finish():
        prod = ol_ref[...]
        absprod = jnp.abs(prod)
        logmag = jnp.log(jnp.maximum(absprod, 1e-30)) + ascale + bscale
        logmag = jnp.where(absprod > 0, logmag, LOG_FLOOR_F32)
        # Rows/columns whose scale sits at the finite floor are GOOM zeros:
        # the plain-max scaling would otherwise resurrect them as exp(0)=1.
        dead = (ascale <= LOG_FLOOR_F32 + 0.5) | (bscale <= LOG_FLOOR_F32 + 0.5)
        logmag = jnp.where(dead, LOG_FLOOR_F32, logmag)
        logmag = jnp.maximum(logmag, LOG_FLOOR_F32)
        ol_ref[...] = logmag
        osg_ref[...] = jnp.where(prod < 0, -1.0, 1.0).astype(osg_ref.dtype)


def lmme_pallas(al, asg, bl, bsg, *, bm=None, bn=None, bk=None,
                interpret=True):
    """Tiled Pallas LMME: (al, asg) [n,d] x (bl, bsg) [d,m] -> [n,m] pair.

    Scaling constants are computed here (cheap O(nd) jnp work, detached) and
    streamed to the kernel per-tile; everything O(n*d*m) happens inside the
    kernel.
    """
    n, d = al.shape
    d2, m = bl.shape
    assert d == d2, f"shape mismatch {al.shape} x {bl.shape}"

    bm = bm or min(DEFAULT_BM, n)
    bn = bn or min(DEFAULT_BN, m)
    bk = bk or min(DEFAULT_BK, d)
    assert n % bm == 0 and m % bn == 0 and d % bk == 0, (
        f"dims ({n},{d},{m}) must divide tiles ({bm},{bk},{bn})")

    # eq. 11 scaling constants (plain max — see goom.lmme for rationale).
    ascale = jax.lax.stop_gradient(jnp.max(al, axis=1, keepdims=True))
    ascale = jnp.maximum(ascale, LOG_FLOOR_F32)
    bscale = jax.lax.stop_gradient(jnp.max(bl, axis=0, keepdims=True))
    bscale = jnp.maximum(bscale, LOG_FLOOR_F32)

    grid = (n // bm, m // bn, d // bk)
    nsteps_k = grid[2]

    out_shape = [
        jax.ShapeDtypeStruct((n, m), jnp.float32),  # logmag (accumulator)
        jax.ShapeDtypeStruct((n, m), al.dtype),     # sign
    ]
    ol, osg = pl.pallas_call(
        partial(_lmme_kernel, nsteps_k=nsteps_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),   # ascale
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),   # bscale
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # al
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # asg
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # bl
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # bsg
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # ol
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # osg
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(ascale, bscale, al, asg, bl, bsg)
    return ol.astype(al.dtype), osg


def mxu_utilization_estimate(n, d, m, bm, bn, bk):
    """Estimated MXU utilization of the kernel: useful dot FLOPs over dot
    FLOPs plus the elementwise scale/exp/log overhead, assuming the VPU
    issues 1 elementwise op per MXU-equivalent slot. Used by DESIGN.md §Perf
    to compare against the paper's ~2x-matmul LMME cost."""
    dot_flops = 2.0 * n * d * m
    # per-tile elementwise work: 2*(bm*bk + bk*bn) exp/mul + bm*bn log/abs
    tiles = (n // bm) * (m // bn) * (d // bk)
    elem = tiles * (2.0 * (bm * bk + bk * bn)) + (n / bm) * (m / bn) * (3.0 * bm * bn)
    return dot_flops / (dot_flops + elem)
