"""Layer-2: the paper's §4.3 deep RNN with non-diagonal GOOM-SSM recurrences.

Architecture (per paper):
  embedding -> N x residual recurrent layer -> task head

Residual recurrent layer, per token, multiple heads:
  1. LayerNorm + linear(+bias) -> per-head inputs u_t
  2. non-diagonal linear SSM  x_t = A x_{t-1} + B u_t  per head, computed
     over GOOMs via a parallel prefix scan (eq. 26) with NO stabilization —
     recurrent magnitudes fluctuate freely in log space;
  3. log-rescaled export back to floats (eq. 27), y_t = C x_t + D u_t,
     GLU, linear over flattened heads, residual add.

The whole train step (forward + loss + backward + Adam update) is one jitted
function, lowered once by aot.py; the Rust Layer-3 trainer only feeds
batches and carries the parameter/optimizer buffers.
"""

from functools import partial

import jax
import jax.numpy as jnp

from . import goom


# ------------------------------------------------------------- config ------


class RnnConfig:
    """Static hyperparameters (baked into the lowered HLO)."""

    def __init__(self, vocab=16, d_model=32, n_heads=2, d_head=8, d_state=8,
                 n_layers=2, seq_len=48, batch=16, mode="lm",
                 lr=3e-3, beta1=0.9, beta2=0.999, adam_eps=1e-8):
        assert n_heads * d_head <= d_model * 4
        self.vocab = vocab
        self.d_model = d_model
        self.n_heads = n_heads
        self.d_head = d_head
        self.d_state = d_state
        self.n_layers = n_layers
        self.seq_len = seq_len
        self.batch = batch
        # "lm": next-token loss at every position.
        # "cls": classification from the LAST position only (targets [B]).
        self.mode = mode
        self.lr = lr
        self.beta1 = beta1
        self.beta2 = beta2
        self.adam_eps = adam_eps


# ------------------------------------------------------------- params ------


def init_params(cfg, key):
    """Initialize the parameter pytree (a flat dict of named arrays)."""
    keys = jax.random.split(key, 4 + cfg.n_layers * 8)
    k = iter(keys)

    def dense(key, fan_in, shape):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    p = {"embed": dense(next(k), 1.0, (cfg.vocab, cfg.d_model))}
    h, dh, ds = cfg.n_heads, cfg.d_head, cfg.d_state
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        p[pre + "ln_scale"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[pre + "ln_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p[pre + "w_in"] = dense(next(k), cfg.d_model, (cfg.d_model, h * dh))
        p[pre + "b_in"] = jnp.zeros((h * dh,), jnp.float32)
        # Non-diagonal transition: near-identity + small noise. The paper
        # needs NO spectral constraint — GOOMs absorb growth/decay.
        a = jnp.eye(ds)[None].repeat(h, 0) + 0.05 * jax.random.normal(next(k), (h, ds, ds))
        p[pre + "A"] = a.astype(jnp.float32)
        p[pre + "B"] = dense(next(k), dh, (h, ds, dh))
        p[pre + "C"] = dense(next(k), ds, (h, 2 * dh, ds))
        p[pre + "D"] = dense(next(k), dh, (h, 2 * dh, dh))
        p[pre + "w_out"] = dense(next(k), h * dh, (h * dh, cfg.d_model))
        p[pre + "b_out"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["head_ln_scale"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["head_ln_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["head_w"] = dense(next(k), cfg.d_model, (cfg.d_model, cfg.vocab))
    p["head_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


def param_names(cfg):
    """Deterministic parameter ordering (the manifest/runtime contract)."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        names += [pre + s for s in
                  ["ln_scale", "ln_bias", "w_in", "b_in", "A", "B", "C", "D",
                   "w_out", "b_out"]]
    names += ["head_ln_scale", "head_ln_bias", "head_w", "head_b"]
    return names


# ------------------------------------------------------------ forward ------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _ssm_layer_goom(u, a, b):
    """The non-diagonal GOOM-SSM recurrence for ONE head over a batch.

    u: [B, T, dh] float inputs; a: [ds, ds]; b: [ds, dh].
    Returns x: [B, T, ds] floats, exported via eq. 27 per (batch, step).

    Everything between to_goom and rescale_export happens in log space; the
    scan is a parallel prefix scan (eq. 26) with no stabilization.
    """
    B, T, dh = u.shape
    ds = a.shape[0]
    # GOOM-map parameters and inputs (custom VJPs, eq. 4-6).
    al, asg = goom.to_goom(a)
    bl, bsg = goom.to_goom(b)
    ul, usg = goom.to_goom(u)

    # b'_t = LMME(B', u'_t): [B, T, ds, 1] column states.
    # Batched over (B, T) via broadcasting inside goom.lmme.
    ul_col = ul[..., :, None]  # [B,T,dh,1]
    usg_col = usg[..., :, None]
    bias_l, bias_s = goom.lmme((jnp.broadcast_to(bl, (B, T, ds, dh)),
                                jnp.broadcast_to(bsg, (B, T, ds, dh))),
                               (ul_col, usg_col))  # [B,T,ds,1]

    # Transition stack: same A' at every step.
    a_l = jnp.broadcast_to(al, (B, T, ds, ds))
    a_s = jnp.broadcast_to(asg, (B, T, ds, ds))

    def combine(earlier, later):
        (a1l, a1s, b1l, b1s) = earlier
        (a2l, a2s, b2l, b2s) = later
        al_, as_ = goom.lmme((a2l, a2s), (a1l, a1s))
        pl_, ps_ = goom.lmme((a2l, a2s), (b1l, b1s))
        bl_, bs_ = goom.goom_add((pl_, ps_), (b2l, b2s))
        return al_, as_, bl_, bs_

    elems = (a_l, a_s, bias_l, bias_s)
    # Scan over axis=1 (time).
    _, _, xl, xs = jax.lax.associative_scan(combine, elems, axis=1)
    # eq. 27 export, rescaled per (batch, step) slice so every exported
    # state lands in (-e^2, e^2) while gradients flow through from_goom.
    x, _c = goom.rescale_export(xl[..., 0], xs[..., 0], axis=-1)
    return x  # [B, T, ds]


def forward(cfg, params, tokens):
    """tokens [B, T] int32 -> logits [B, T, vocab]."""
    x = params["embed"][tokens]  # [B, T, d_model]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layer_norm(x, params[pre + "ln_scale"], params[pre + "ln_bias"])
        u = jnp.matmul(h, params[pre + "w_in"]) + params[pre + "b_in"]
        B, T = u.shape[0], u.shape[1]
        u = u.reshape(B, T, cfg.n_heads, cfg.d_head)
        outs = []
        for hd in range(cfg.n_heads):  # static unroll over heads
            xh = _ssm_layer_goom(u[:, :, hd, :], params[pre + "A"][hd],
                                 params[pre + "B"][hd])
            # y_t = C x_t + D u_t over floats, then GLU.
            y = (jnp.einsum("od,btd->bto", params[pre + "C"][hd], xh)
                 + jnp.einsum("od,btd->bto", params[pre + "D"][hd],
                              u[:, :, hd, :]))
            y1, y2 = jnp.split(y, 2, axis=-1)
            outs.append(y1 * jax.nn.sigmoid(y2))  # GLU
        glu = jnp.concatenate(outs, axis=-1)  # [B, T, h*dh]
        x = x + jnp.matmul(glu, params[pre + "w_out"]) + params[pre + "b_out"]
    h = _layer_norm(x, params["head_ln_scale"], params["head_ln_bias"])
    return jnp.matmul(h, params["head_w"]) + params["head_b"]


def loss_fn(cfg, params, tokens, targets):
    logits = forward(cfg, params, tokens)
    if cfg.mode == "cls":
        logits = logits[:, -1, :]  # classify from the last position
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)
        return jnp.mean(nll)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# --------------------------------------------------------------- adam ------


def adam_update(cfg, params, grads, m, v, step):
    """One Adam step over the flat dicts. step counts from 1."""
    b1, b2 = cfg.beta1, cfg.beta2
    new_p, new_m, new_v = {}, {}, {}
    t = step.astype(jnp.float32)
    for k in params:
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * g * g
        mhat = m_k / (1 - b1 ** t)
        vhat = v_k / (1 - b2 ** t)
        new_p[k] = params[k] - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v


def make_train_step(cfg):
    """Returns train_step(params, m, v, step, tokens, targets) ->
    (params', m', v', loss). This is the function aot.py lowers."""

    def train_step(params, m, v, step, tokens, targets):
        loss, grads = jax.value_and_grad(partial(loss_fn, cfg))(
            params, tokens, targets)
        new_p, new_m, new_v = adam_update(cfg, params, grads, m, v, step + 1)
        return new_p, new_m, new_v, loss

    return train_step


def make_forward(cfg):
    def fwd(params, tokens):
        return forward(cfg, params, tokens)

    return fwd
