"""Layer-2 GOOM operations in JAX.

GOOM tensors are ``(logmag, sign)`` pairs of real arrays — the explicit form
of the paper's complex-typed GOOMs (imaginary component 0 or pi == sign
+1/-1). ``logmag = -inf`` encodes exact zero; by the paper's convention zero
is non-negative (sign +1).

This module provides:

* ``to_goom`` / ``from_goom``    — the paper's eq. 4 / eq. 7 maps, with the
  custom derivatives of eq. 5, 6 and 8 implemented as ``jax.custom_vjp``.
* ``goom_mul`` / ``goom_add``    — Examples 1 and 2 (signed log-sum-exp).
* ``lmme`` / ``lmme_exact``      — paper §3.2, delegating the hot path to the
  Pallas kernel (Layer 1) or a pure-jnp fallback.
* ``goom_scan_affine``           — parallel prefix scan of the affine GOOM
  recurrence x'_t = LSE(LMME(A', x'_{t-1}), b'_t) (paper eq. 26) via
  ``jax.lax.associative_scan``.
* ``rescale_export``             — the paper's eq. 27 log-rescaled export.

Everything here is build-time Python: it exists to be traced by jax.jit and
lowered to HLO text by ``aot.py``. Nothing imports torch; nothing runs at
serving time.
"""

from functools import partial

import jax
import jax.numpy as jnp

# Finite floor for log(0): the paper's footnote 5 uses log(SNN^2) where SNN
# is the smallest normal number of the component format. For f32 that is
# 2*ln(1.1754944e-38) ~= -174.673, which exponentiates to exactly 0.0 in f32.
LOG_FLOOR_F32 = -174.673
# Epsilon for the redefined derivatives (eq. 6 / eq. 8).
EPS_F32 = 1e-30


def _signum_nonneg(x):
    """sign(x) with sign(0) = +1 (paper: zero is non-negative)."""
    return jnp.where(x < 0, -1.0, 1.0).astype(x.dtype)


# ----------------------------------------------------------- to/from goom --


@jax.custom_vjp
def to_goom(x):
    """Map a real tensor to a GOOM pair (paper eq. 4).

    Uses the finite-floor variant (option (b) of §3.1) so downstream graphs
    never see -inf: log(|x|) is clamped below at LOG_FLOOR_F32.
    """
    logmag = jnp.log(jnp.maximum(jnp.abs(x), jnp.exp(jnp.asarray(LOG_FLOOR_F32, x.dtype))))
    logmag = jnp.maximum(logmag, LOG_FLOOR_F32)
    return logmag.astype(x.dtype), _signum_nonneg(x)


def _to_goom_fwd(x):
    return to_goom(x), x


def _to_goom_bwd(x, cot):
    g_logmag, _g_sign = cot
    # eq. 5 (abs' = sign, never 0) composed with eq. 6 (1/(|x| + eps)):
    grad = g_logmag * _signum_nonneg(x) / (jnp.abs(x) + EPS_F32)
    return (grad,)


to_goom.defvjp(_to_goom_fwd, _to_goom_bwd)


@jax.custom_vjp
def from_goom(logmag, sign):
    """Map a GOOM pair back to a real tensor (paper eq. 7)."""
    return sign * jnp.exp(logmag)


def _from_goom_fwd(logmag, sign):
    x = from_goom(logmag, sign)
    return x, x


def _from_goom_bwd(x, g):
    # eq. 8: derivative w.r.t. the GOOM is exp(x') shifted away from zero by
    # +/- eps, so gradients vanish only when the backpropagated error does.
    d = x + EPS_F32 * _signum_nonneg(x)
    return g * d, jnp.zeros_like(x)


from_goom.defvjp(_from_goom_fwd, _from_goom_bwd)


# ------------------------------------------------------- scalar operations --


def goom_mul(a, b):
    """Real multiplication = GOOM addition (paper Example 1). a,b = pairs."""
    (al, asg), (bl, bsg) = a, b
    return al + bl, asg * bsg


def goom_add(a, b):
    """Real addition = signed log-sum-exp of two GOOM pairs (Example 2)."""
    (al, asg), (bl, bsg) = a, b
    hi = jnp.maximum(al, bl)
    lo = jnp.minimum(al, bl)
    hi_sign = jnp.where(al >= bl, asg, bsg)
    lo_sign = jnp.where(al >= bl, bsg, asg)
    # r = s_hi + s_lo * exp(lo - hi) in [-2, 2]; exact-cancellation -> floor.
    r = hi_sign + lo_sign * jnp.exp(lo - hi)
    absr = jnp.abs(r)
    logmag = hi + jnp.log(jnp.maximum(absr, EPS_F32))
    logmag = jnp.where(absr > 0, logmag, LOG_FLOOR_F32)
    # hi == -inf (both zero) -> floor.
    logmag = jnp.maximum(logmag, LOG_FLOOR_F32)
    return logmag, _signum_nonneg(r)


def goom_lse(logmag, sign, axis=-1):
    """Signed log-sum-exp reduction along ``axis`` (the paper's LSE)."""
    m = jnp.max(logmag, axis=axis, keepdims=True)
    m_safe = jnp.maximum(m, LOG_FLOOR_F32)
    acc = jnp.sum(sign * jnp.exp(logmag - m_safe), axis=axis)
    absacc = jnp.abs(acc)
    out_l = jnp.squeeze(m_safe, axis) + jnp.log(jnp.maximum(absacc, EPS_F32))
    out_l = jnp.where(absacc > 0, out_l, LOG_FLOOR_F32)
    out_l = jnp.maximum(out_l, LOG_FLOOR_F32)
    return out_l, _signum_nonneg(acc)


# ------------------------------------------------------------------- LMME --


def lmme(a, b, kernel=None):
    """LMME(A', B') over batched GOOM pairs (paper §3.2 eq. 10).

    ``a = (logmag, sign)`` with shape [..., n, d]; ``b`` with [..., d, m].
    The compromise implementation: per-row/per-column log-scaling constants
    (detached, eq. 11), one real matmul on the scaled exponentials, then log
    and rescale. ``kernel`` optionally substitutes the Pallas Layer-1 kernel
    for the unbatched [n,d]x[d,m] case.
    """
    (al, asg), (bl, bsg) = a, b
    if kernel is not None and al.ndim == 2 and bl.ndim == 2:
        return kernel(al, asg, bl, bsg)
    # eq. 11 scaling constants, detached from the gradient graph. We use the
    # plain row/col max (not clamped at 0 — see rust goom::lmme docs: the
    # clamp underflows all-tiny inputs; plain max coincides otherwise).
    ascale = jax.lax.stop_gradient(jnp.max(al, axis=-1, keepdims=True))
    ascale = jnp.maximum(ascale, LOG_FLOOR_F32)  # all-zero rows
    bscale = jax.lax.stop_gradient(jnp.max(bl, axis=-2, keepdims=True))
    bscale = jnp.maximum(bscale, LOG_FLOOR_F32)
    ea = asg * jnp.exp(al - ascale)
    eb = bsg * jnp.exp(bl - bscale)
    prod = jnp.matmul(ea, eb)  # scaled matmul over R (the delegated hot path)
    absprod = jnp.abs(prod)
    out_l = jnp.log(jnp.maximum(absprod, EPS_F32)) + ascale + bscale
    out_l = jnp.where(absprod > 0, out_l, LOG_FLOOR_F32)
    # Floor-scaled rows/cols are GOOM zeros; plain-max scaling would
    # otherwise resurrect them as exp(0) = 1.
    dead = (ascale <= LOG_FLOOR_F32 + 0.5) | (bscale <= LOG_FLOOR_F32 + 0.5)
    out_l = jnp.where(dead, LOG_FLOOR_F32, out_l)
    out_l = jnp.maximum(out_l, LOG_FLOOR_F32)
    return out_l, _signum_nonneg(prod)


def lmme_exact(a, b):
    """Exact LMME (paper eq. 9): signed LSE of pairwise sums, O(ndm) space.

    Used as an oracle and for precision studies; never exponentiates at full
    magnitude.
    """
    (al, asg), (bl, bsg) = a, b
    s = al[..., :, :, None] + bl[..., None, :, :]  # [..., n, d, m]
    sg = asg[..., :, :, None] * bsg[..., None, :, :]
    return goom_lse(s, sg, axis=-2)


# ------------------------------------------------------------------- scan --


def goom_scan_affine(a_seq, b_seq, reverse=False):
    """Parallel prefix scan of x'_t = LSE(LMME(A'_t, x'_{t-1}), b'_t)
    (paper eq. 26) via ``jax.lax.associative_scan``.

    ``a_seq = (logmag, sign)`` with shape [T, d, d] (non-diagonal transition
    GOOMs); ``b_seq`` with shape [T, d, m] (bias GOOMs, m columns of state).
    Returns the stacked states x'_1..x'_T as a pair of [T, d, m] arrays.

    The scan element is the affine map (A', b'); composition is
    (A2', b2') after (A1', b1')  =  (LMME(A2', A1'), LSE(LMME(A2', b1'), b2')).
    """

    def combine(earlier, later):
        a1, b1 = earlier
        a2, b2 = later
        a = lmme(a2, a1)
        ab = lmme(a2, b1)
        b = goom_add(ab, b2)
        return a, b

    elems = ((a_seq[0], a_seq[1]), (b_seq[0], b_seq[1]))
    (_, _), (xl, xs) = jax.lax.associative_scan(combine, elems, reverse=reverse)
    return xl, xs


def matrix_chain_scan(a_seq):
    """Prefix scan of the pure matrix chain H_t = A_t ... A_1 over GOOMs
    (the Fig. 1 / eq. 24 PSCAN(LMME) primitive).

    ``a_seq = (logmag, sign)`` with shape [T, d, d]. Returns [T, d, d] pairs.
    """

    def combine(earlier, later):
        return lmme(later, earlier)

    return jax.lax.associative_scan(combine, a_seq)


# ----------------------------------------------------------------- export --


def rescale_export(logmag, sign, axis=None, margin=2.0):
    """The paper's eq. 27: log-rescale then exponentiate, so the exported
    floats land in (-e^margin, e^margin) regardless of GOOM magnitude.

    Returns (x_scaled, c) with c detached from the gradient graph.
    """
    c = jnp.max(logmag, axis=axis, keepdims=axis is not None)
    c = jax.lax.stop_gradient(c)
    x = from_goom(logmag - c + margin, sign)
    return x, c
