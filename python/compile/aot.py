"""AOT compiler: lowers every Layer-1/Layer-2 graph to HLO text and writes
the artifact manifest the Rust runtime consumes.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (see DESIGN.md §2):
  lmme_d16 / lmme_d64      one fused Pallas LMME
  chain_block_d{8,16,32}   K=64 LMME chain steps + max-logmag trace (Fig. 1)
  lle_scan_d3_T512         eq. 24 parallel LLE numerator (§4.2.2)
  spectrum_d3_T256         §4.2.1 full parallel spectrum
  rnn_train_step/forward   §4.3 GOOM-SSM RNN (copy-memory config)
  manifest.json            input/output specs for every artifact
  rnn_init.gbin            initial params + Adam state (custom container)

Run once via `make artifacts`; never on the request path.
"""

import argparse
import functools
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import goom, lyapunov, model
from .kernels.lmme import lmme_pallas

CHAIN_BLOCK_K = 64
LLE_D, LLE_T = 3, 512
SPEC_D, SPEC_T = 3, 256


# ------------------------------------------------------------- lowering --


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_and_save(fn, specs, out_dir, name, input_names, output_names,
                   meta=None):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "path": f"{name}.hlo.txt",
        "inputs": [
            {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
            for n, s in zip(input_names, specs)
        ],
        "outputs": output_names,
    }
    if meta:
        entry["meta"] = meta
    print(f"  wrote {path} ({len(text)} chars, {len(specs)} inputs)")
    return entry


# ------------------------------------------------------------ gbin I/O --

_DTYPE_TAGS = {"float32": 0, "int32": 1, "float64": 2}


def write_gbin(path, tensors):
    """tensors: list of (name, np.ndarray). Little-endian custom container."""
    with open(path, "wb") as f:
        f.write(b"GBIN")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", _DTYPE_TAGS[str(arr.dtype)]))
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<Q", dim))
            f.write(arr.tobytes())


# ------------------------------------------------------------ artifacts --


def build_lmme(out_dir, d):
    def fn(al, asg, bl, bsg):
        return lmme_pallas(al, asg, bl, bsg, bm=d, bn=d, bk=d)

    s = spec((d, d))
    return lower_and_save(
        fn, [s, s, s, s], out_dir, f"lmme_d{d}",
        ["a_logmag", "a_sign", "b_logmag", "b_sign"],
        ["out_logmag", "out_sign"])


def build_chain_block(out_dir, d, k=CHAIN_BLOCK_K):
    """One Fig.-1 chain block: scan K LMME steps, carry the state, emit the
    per-step max logmag (the growth trace the driver logs)."""

    def fn(jl, js, sl, ss):
        def body(carry, step):
            cl, cs = carry
            nl, ns = goom.lmme((step[0], step[1]), (cl, cs))
            return (nl, ns), jnp.max(nl)

        (ol, os_), trace = jax.lax.scan(body, (sl, ss), (jl, js))
        return ol, os_, trace

    return lower_and_save(
        fn,
        [spec((k, d, d)), spec((k, d, d)), spec((d, d)), spec((d, d))],
        out_dir, f"chain_block_d{d}",
        ["j_logmag", "j_sign", "state_logmag", "state_sign"],
        ["state_logmag", "state_sign", "max_logmag_trace"],
        meta={"block_steps": k})


def build_lle(out_dir, d=LLE_D, t=LLE_T):
    fn = lyapunov.make_lle_scan(d, t)
    return lower_and_save(
        fn,
        [spec((t, d, d)), spec((t, d, d)), spec((d,)), spec(())],
        out_dir, f"lle_scan_d{d}_T{t}",
        ["j_logmag", "j_sign", "u0", "dt"],
        ["lle", "log_norm_trace"],
        meta={"d": d, "t": t})


def build_spectrum(out_dir, d=SPEC_D, t=SPEC_T):
    fn = lyapunov.make_spectrum(d, t)
    return lower_and_save(
        fn,
        [spec((t, d, d)), spec((t, d, d)), spec(())],
        out_dir, f"spectrum_d{d}_T{t}",
        ["j_logmag", "j_sign", "dt"],
        ["lambda", "n_resets"],
        meta={"d": d, "t": t})


def build_rnn(out_dir, cfg, tag):
    names = model.param_names(cfg)

    def train_flat(*args):
        n = len(names)
        params = dict(zip(names, args[:n]))
        m = dict(zip(names, args[n:2 * n]))
        v = dict(zip(names, args[2 * n:3 * n]))
        step, tokens, targets = args[3 * n:]
        new_p, new_m, new_v, loss = model.make_train_step(cfg)(
            params, m, v, step, tokens, targets)
        out = tuple(new_p[k] for k in names) + tuple(new_m[k] for k in names) \
            + tuple(new_v[k] for k in names) + (loss,)
        return out

    def forward_flat(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens = args[len(names)]
        return (model.forward(cfg, params, tokens),)

    params = model.init_params(cfg, jax.random.PRNGKey(0))
    p_specs = [spec(params[k].shape) for k in names]
    target_shape = (cfg.batch,) if cfg.mode == "cls" else (cfg.batch, cfg.seq_len)
    train_specs = (p_specs + p_specs + p_specs
                   + [spec((), jnp.int32),
                      spec((cfg.batch, cfg.seq_len), jnp.int32),
                      spec(target_shape, jnp.int32)])
    input_names = ([f"param.{k}" for k in names]
                   + [f"adam_m.{k}" for k in names]
                   + [f"adam_v.{k}" for k in names]
                   + ["step", "tokens", "targets"])
    output_names = ([f"param.{k}" for k in names]
                    + [f"adam_m.{k}" for k in names]
                    + [f"adam_v.{k}" for k in names]
                    + ["loss"])
    meta = {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "d_head": cfg.d_head, "d_state": cfg.d_state, "n_layers": cfg.n_layers,
        "seq_len": cfg.seq_len, "batch": cfg.batch, "mode": cfg.mode,
        "lr": cfg.lr, "param_names": names,
        "n_params": int(sum(int(np.prod(params[k].shape)) for k in names)),
        "init_gbin": f"rnn_{tag}_init.gbin",
    }
    entries = [lower_and_save(train_flat, train_specs, out_dir,
                              f"rnn_{tag}_train_step", input_names,
                              output_names, meta=meta)]
    entries.append(lower_and_save(
        forward_flat, p_specs + [spec((cfg.batch, cfg.seq_len), jnp.int32)],
        out_dir, f"rnn_{tag}_forward",
        [f"param.{k}" for k in names] + ["tokens"], ["logits"], meta=meta))

    # Initial params + zeroed Adam state in one gbin.
    tensors = [(f"param.{k}", np.asarray(params[k])) for k in names]
    tensors += [(f"adam_m.{k}", np.zeros_like(np.asarray(params[k]))) for k in names]
    tensors += [(f"adam_v.{k}", np.zeros_like(np.asarray(params[k]))) for k in names]
    write_gbin(os.path.join(out_dir, f"rnn_{tag}_init.gbin"), tensors)
    print(f"  wrote rnn_{tag}_init.gbin ({meta['n_params']} params)")
    return entries


COPY_CFG = model.RnnConfig(vocab=16, d_model=32, n_heads=2, d_head=8,
                           d_state=8, n_layers=2, seq_len=48, batch=16,
                           mode="lm", lr=3e-3)

# Pixel-sequence classification (sMNIST substitute): classify a 64-step
# quantized pixel sequence from the LAST position (paper Fig. 4 right).
PIXEL_CFG = model.RnnConfig(vocab=8, d_model=32, n_heads=2, d_head=8,
                            d_state=8, n_layers=2, seq_len=64, batch=16,
                            mode="cls", lr=3e-3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact groups: lmme,chain,lle,spectrum,rnn")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    groups = set(args.only.split(",")) if args.only else \
        {"lmme", "chain", "lle", "spectrum", "rnn"}

    entries = []
    if "lmme" in groups:
        print("[lmme]")
        entries.append(build_lmme(out_dir, 16))
        entries.append(build_lmme(out_dir, 64))
    if "chain" in groups:
        print("[chain blocks]")
        for d in (8, 16, 32):
            entries.append(build_chain_block(out_dir, d))
    if "lle" in groups:
        print("[lle scan]")
        entries.append(build_lle(out_dir))
    if "spectrum" in groups:
        print("[spectrum]")
        entries.append(build_spectrum(out_dir))
    if "rnn" in groups:
        print("[rnn]")
        entries.extend(build_rnn(out_dir, COPY_CFG, "copy"))
        entries.extend(build_rnn(out_dir, PIXEL_CFG, "pixel"))

    manifest_path = os.path.join(out_dir, "manifest.json")
    existing = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            existing = {e["name"]: e for e in json.load(f)["artifacts"]}
    for e in entries:
        existing[e["name"]] = e
    with open(manifest_path, "w") as f:
        json.dump({"artifacts": sorted(existing.values(), key=lambda e: e["name"])},
                  f, indent=1)
    print(f"wrote {manifest_path} ({len(existing)} artifacts)")


if __name__ == "__main__":
    main()
