"""Layer-2 Lyapunov graphs, lowered to HLO by aot.py.

Two graphs:

* ``make_lle_scan(cfg)``      — paper eq. 24: prefix scan of LMME over a
  Jacobian stack applied to u0, no normalization anywhere; returns the LLE
  numerator log||s_T|| plus the per-step log-norm trace.

* ``make_spectrum(cfg)``      — paper §4.2.1 groups (a)-(d) as ONE fused
  graph: selective-reset prefix scan over GOOMs (reset = in-graph batched
  MGS QR of the log-rescaled state), batch QR of every state, push each
  Jacobian through its predecessor basis, and average the log|diag R|.

Everything is pure jnp — in particular QR is hand-rolled modified
Gram-Schmidt (mirroring rust linalg::qr_mgs) so the lowered HLO contains no
LAPACK custom-calls and runs on any PJRT backend.
"""

import jax
import jax.numpy as jnp

from . import goom

LOG_FLOOR_F32 = goom.LOG_FLOOR_F32


# ----------------------------------------------------------- batched MGS --


def mgs_qr(x):
    """Thin MGS QR of x [..., n, d] with d static; diag(R) >= 0.

    Unrolled over columns (d is small and static in these graphs), fully
    traceable, custom-call-free. Returns (q [...,n,d], r [...,d,d]).
    """
    d = x.shape[-1]
    cols = [x[..., :, k] for k in range(d)]
    q_cols = []
    r_rows = [[jnp.zeros(x.shape[:-2], x.dtype) for _ in range(d)] for _ in range(d)]
    for k in range(d):
        v = cols[k]
        rkk = jnp.sqrt(jnp.sum(v * v, axis=-1) + 1e-30)
        r_rows[k][k] = rkk
        qk = v / rkk[..., None]
        for j in range(k + 1, d):
            s = jnp.sum(qk * cols[j], axis=-1)
            r_rows[k][j] = s
            cols[j] = cols[j] - s[..., None] * qk
        q_cols.append(qk)
    q = jnp.stack(q_cols, axis=-1)
    r = jnp.stack([jnp.stack(row, axis=-1) for row in r_rows], axis=-2)
    return q, r


# ----------------------------------------------------- log-space helpers --


def col_log_norms(xl):
    """0.5*LSE(2*logmag) per column: xl [..., n, d] -> [..., d]."""
    m = jnp.max(xl, axis=-2, keepdims=True)
    m = jnp.maximum(m, LOG_FLOOR_F32)
    acc = jnp.sum(jnp.exp(2.0 * (xl - m)), axis=-2)
    return jnp.squeeze(m, -2) + 0.5 * jnp.log(jnp.maximum(acc, 1e-30))


def max_pairwise_col_cosine(xl, xs):
    """Max |cosine| over column pairs, computed stably in log space.
    xl, xs: [..., n, d]. Returns [...]."""
    d = xl.shape[-1]
    norms = col_log_norms(xl)  # [..., d]
    worst = jnp.zeros(xl.shape[:-2], xl.dtype)
    for i in range(d):
        for j in range(i + 1, d):
            s = xl[..., :, i] + xl[..., :, j]  # [..., n]
            sg = xs[..., :, i] * xs[..., :, j]
            m = jnp.maximum(jnp.max(s, axis=-1), LOG_FLOOR_F32)
            acc = jnp.sum(sg * jnp.exp(s - m[..., None]), axis=-1)
            log_dot = m + jnp.log(jnp.maximum(jnp.abs(acc), 1e-30))
            log_cos = log_dot - norms[..., i] - norms[..., j]
            cos = jnp.exp(jnp.minimum(log_cos, 0.0))
            worst = jnp.maximum(worst, cos)
    return worst


def orthonormalize_goom(xl, xs):
    """The reset function R (paper §4.2.1(a)): log-normalize columns,
    export to floats, MGS QR, log-map Q back."""
    norms = col_log_norms(xl)  # [..., d]
    xl_n = xl - norms[..., None, :]
    real = xs * jnp.exp(jnp.maximum(xl_n, LOG_FLOOR_F32))
    q, _ = mgs_qr(real)
    ql = jnp.log(jnp.maximum(jnp.abs(q), 1e-30))
    ql = jnp.maximum(ql, LOG_FLOOR_F32)
    # Entries that are exactly zero stay at the floor.
    return ql, jnp.where(q < 0, -1.0, 1.0).astype(xs.dtype)


# ------------------------------------------------------------- LLE graph --


def make_lle_scan(d, t_steps):
    """Returns lle(jl, js, u0, dt) with jl/js [T,d,d], u0 [d], dt scalar.

    Output: (lle, log_norm_trace [T]) — eq. 24 with the whole prefix trace
    (the paper's PSCAN exposes all interim states; the trace is what the
    rust driver logs)."""

    def lle(jl, js, u0, dt):
        # H_t = J_t ... J_1 via PSCAN(LMME).
        hl, hs = goom.matrix_chain_scan((jl, js))  # [T,d,d]
        # s_t = H_t u0 over GOOMs (u0 is representable; log-map in-graph).
        u0l, u0s = goom.to_goom(u0[:, None])  # [d,1]
        sl, ss = goom.lmme((hl, hs), (jnp.broadcast_to(u0l, (t_steps, d, 1)),
                                      jnp.broadcast_to(u0s, (t_steps, d, 1))))
        # log||s_t|| = 0.5 * LSE(2 logmag) per step.
        sl2 = sl[..., 0]  # [T, d]
        m = jnp.maximum(jnp.max(sl2, axis=-1), LOG_FLOOR_F32)
        acc = jnp.sum(jnp.exp(2.0 * (sl2 - m[:, None])), axis=-1)
        log_norms = m + 0.5 * jnp.log(jnp.maximum(acc, 1e-30))  # [T]
        lle_val = log_norms[-1] / (dt * t_steps)
        return lle_val, log_norms

    return lle


# -------------------------------------------------------- spectrum graph --


def make_spectrum(d, t_steps, threshold=0.995):
    """Returns spectrum(jl, js, dt) -> (lambda [d], n_resets).

    Groups (a)-(d) of paper §4.2.1 in one graph. The scan element is the
    affine pair (A', B') plus a was-reset flag; the combine applies the
    eq. 28 selective reset to the earlier element, then composes.
    """

    def combine(earlier, later):
        a1l, a1s, b1l, b1s, f1 = earlier
        a2l, a2s, b2l, b2s, f2 = later
        # Selective reset of the earlier tuple (once-only, guarded by flag).
        cos = max_pairwise_col_cosine(a1l, a1s)
        a1_nonzero = jnp.max(a1l, axis=(-2, -1)) > LOG_FLOOR_F32 + 1.0
        fire = (cos > threshold) & (f1 < 0.5) & a1_nonzero
        rl, rs = orthonormalize_goom(a1l, a1s)
        zl = jnp.full_like(a1l, LOG_FLOOR_F32)
        zs = jnp.ones_like(a1s)
        a1l = jnp.where(fire[..., None, None], zl, a1l)
        a1s = jnp.where(fire[..., None, None], zs, a1s)
        b1l_new = jnp.where(fire[..., None, None], rl, b1l)
        b1s_new = jnp.where(fire[..., None, None], rs, b1s)
        f1 = jnp.where(fire, 1.0, f1)
        # Ordinary affine composition over GOOMs.
        al, as_ = goom.lmme((a2l, a2s), (a1l, a1s))
        pl, ps = goom.lmme((a2l, a2s), (b1l_new, b1s_new))
        bl, bs = goom.goom_add((pl, ps), (b2l, b2s))
        return al, as_, bl, bs, jnp.maximum(f1, f2)

    def spectrum(jl, js, dt):
        # Scan elements: first = S0 (identity basis), then J_1..J_{T-1}.
        eye = jnp.eye(d, dtype=jl.dtype)
        s0l, s0s = goom.to_goom(eye)
        al = jnp.concatenate([s0l[None], jl[:-1]], axis=0)  # [T,d,d]
        as_ = jnp.concatenate([s0s[None], js[:-1]], axis=0)
        bl = jnp.full_like(al, LOG_FLOOR_F32)
        bs = jnp.ones_like(as_)
        flags = jnp.zeros((t_steps,), jl.dtype)
        scanned = jax.lax.associative_scan(
            combine, (al, as_, bl, bs, flags), axis=0)
        sl_a, ss_a, sl_b, ss_b, flags_out = scanned
        # State = A* + B* (exactly one non-zero per position).
        stl, sts = goom.goom_add((sl_a, ss_a), (sl_b, ss_b))
        # Group (b): log-normalize + export + QR -> Q_{t-1} for every t.
        norms = col_log_norms(stl)
        stl_n = stl - norms[..., None, :]
        real_states = sts * jnp.exp(jnp.maximum(stl_n, LOG_FLOOR_F32))
        q_prev, _ = mgs_qr(real_states)  # [T,d,d]
        # Group (c): S*_t = J_t . Q_{t-1}; jacobian t pairs with state t-1,
        # i.e. jl[t] with q_prev[t] given our element layout.
        real_j = js * jnp.exp(jnp.maximum(jl, LOG_FLOOR_F32))
        s_out = jnp.einsum("tij,tjk->tik", real_j, q_prev)
        # Group (d): QR of every output, mean log|diag R|.
        _, r = mgs_qr(s_out)
        diag = jnp.abs(jnp.stack([r[..., i, i] for i in range(d)], axis=-1))
        logdiag = jnp.log(jnp.maximum(diag, 1e-30))  # [T, d]
        lam = jnp.sum(logdiag, axis=0) / (dt * t_steps)
        return lam, jnp.sum(flags_out)

    return spectrum
